//! `probe bench speed` — raw-speed suite for the §Perf pass (ISSUE 6,
//! extended by ISSUE 10 with the asynchronous control plane).
//!
//! Per rank count (default {16, 32, 64, 128}), all on the `storm`
//! scenario preset:
//!
//! 1. **steps/sec** — wall-clock throughput of the full serving loop
//!    (coordinator + PROBE balancer + simulator) over a calibrated
//!    storm request stream, measured twice: with the synchronous
//!    control plane (`mode = sync`) and with the double-buffered
//!    background pipeline (`mode = pipelined`,
//!    `perf.pipeline_control = true`).
//! 2. **planner-μs/step** — mean wall-clock of Algorithm 1
//!    ([`planner::plan_fabric_with`] with a reused
//!    [`planner::PlanScratch`]) on routed counts at that rank count,
//!    multiplied by the simulated layer depth: the control-plane cost
//!    a real deployment must hide inside the dispatch window.
//! 3. **control-μs exposed/step** — wall-clock control-plane time the
//!    serving loop actually blocked on ([`StepReport`]'s
//!    `control_us_exposed`), plus the overlap efficiency
//!    `hidden / (hidden + exposed)`. Sync mode exposes everything
//!    (efficiency 0); the pipeline should push efficiency toward 1.
//!
//! Results go to `bench_results/BENCH_speed.json`; CI diffs steps/sec
//! against a CI-produced rolling baseline (`BENCH_speed_baseline.json`
//! in the actions cache, bootstrapped from the first run on a fresh
//! cache key — advisory ±15%, no placeholder rows tolerated) and
//! additionally diffs sync vs pipelined steps/sec within the same run.
//!
//! [`StepReport`]: crate::engine::StepReport

use std::time::Instant;

use crate::config::{BalancerKind, Config};
use crate::coordinator::Coordinator;
use crate::perfmodel::expert_compute_time;
use crate::placement::Placement;
use crate::planner::{self, PlanScratch};
use crate::routing::RoutingModel;
use crate::topology::Cluster;
use crate::util::bench::BenchSet;

use super::{make_balancer, SIM_LAYERS};

/// Sweep parameters.
pub struct SpeedParams {
    /// Rank counts swept (must divide the model's expert count).
    pub ranks: Vec<usize>,
    /// Scenario horizon in decode-step units.
    pub steps: usize,
    /// Offered load as a fraction of calibrated decode capacity.
    pub load: f64,
    /// Decode tokens per rank (kept small so the horizon stays short).
    pub batch_per_rank: usize,
    /// Planner invocations timed per rank count.
    pub plans: usize,
    /// Safety cap on decode steps per cell.
    pub max_steps: usize,
    /// Root seed.
    pub seed: u64,
}

impl Default for SpeedParams {
    fn default() -> Self {
        SpeedParams {
            ranks: vec![16, 32, 64, 128],
            steps: 120,
            load: 0.7,
            batch_per_rank: 2,
            plans: 40,
            max_steps: 20_000,
            seed: 41,
        }
    }
}

/// Serving config at `ranks` expert-parallel ranks (flat fabric, sim
/// layer depth, small decode batch).
pub fn speed_cfg(p: &SpeedParams, ranks: usize) -> Config {
    let mut cfg = Config::default();
    assert!(
        cfg.model.n_experts % ranks == 0,
        "rank count {ranks} must divide {} experts",
        cfg.model.n_experts
    );
    cfg.cluster = Cluster::new(ranks, cfg.cluster.profile.clone());
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = p.batch_per_rank;
    cfg.prefill_chunk_per_rank = 1024;
    cfg
}

/// Mean wall-clock seconds of one Algorithm 1 invocation at the
/// config's rank count: `plans` delta plans over drifting routed
/// counts, scratch reused across calls exactly as the PROBE balancer
/// does in steady state.
pub fn planner_secs_per_plan(cfg: &Config, plans: usize, seed: u64) -> f64 {
    let ep = cfg.cluster.ep;
    let model = &cfg.model;
    let hw = &cfg.cluster.profile;
    let fabric = &cfg.cluster.fabric;
    let mut rm = RoutingModel::calibrated(4, model.n_experts, model.top_k, 3, seed);
    let tokens = 64 * ep;
    let mut scratch = PlanScratch::default();
    let mut resident = Placement::sharded(ep, model.n_experts, cfg.probe.max_redundant);
    let slot_caps = vec![cfg.probe.max_redundant; ep];
    let mut windows = vec![0.0; ep];
    let mut total = 0.0f64;
    let mut done = 0usize;
    while done < plans.max(1) {
        let routing = rm.route_step(&vec![0u16; tokens]);
        for lr in &routing.layers {
            if done >= plans.max(1) {
                break;
            }
            let counts = lr.expert_counts_by_source_f64(ep);
            // hiding window: average static-shard compute per rank
            // (the same conservative bootstrap the balancer uses)
            let mut avg = 0.0;
            for row in &counts {
                let c: f64 = row.iter().sum();
                avg += expert_compute_time(c, model, hw);
            }
            avg /= ep as f64;
            windows.iter_mut().for_each(|w| *w = avg);
            let t0 = Instant::now();
            let out = planner::plan_fabric_with(
                &mut scratch,
                &counts,
                &resident,
                model,
                hw,
                fabric,
                &windows,
                &slot_caps,
                &cfg.probe,
            );
            total += t0.elapsed().as_secs_f64();
            resident = out.placement;
            done += 1;
        }
        rm.step_drift();
    }
    total / done as f64
}

/// Outcome of one rank-count serving cell.
#[derive(Debug, Clone)]
pub struct SpeedCell {
    /// Requests in the calibrated storm stream.
    pub submitted: usize,
    /// Requests that completed within the step cap.
    pub completed: usize,
    /// Decode steps executed.
    pub steps: usize,
    /// Wall-clock seconds of the timed serving loop.
    pub wall: f64,
    /// Total control-plane wall-clock hidden behind compute (µs).
    pub control_us_hidden: f64,
    /// Total control-plane wall-clock the step loop blocked on (µs).
    pub control_us_exposed: f64,
}

impl SpeedCell {
    /// Decode steps per wall-clock second.
    pub fn steps_per_sec(&self) -> f64 {
        if self.wall > 0.0 {
            self.steps as f64 / self.wall
        } else {
            0.0
        }
    }

    /// Mean exposed control-plane µs per decode step.
    pub fn control_us_exposed_per_step(&self) -> f64 {
        if self.steps > 0 {
            self.control_us_exposed / self.steps as f64
        } else {
            0.0
        }
    }

    /// Fraction of control-plane wall-clock hidden behind compute
    /// (`hidden / (hidden + exposed)`; 0 when no control time ran).
    pub fn overlap_efficiency(&self) -> f64 {
        let total = self.control_us_hidden + self.control_us_exposed;
        if total > 0.0 {
            self.control_us_hidden / total
        } else {
            0.0
        }
    }
}

/// Run the storm serving loop under PROBE at one rank count, wall-clock
/// timed end to end (stream generation and calibration excluded).
pub fn run_serving_cell(p: &SpeedParams, cfg: &Config) -> Result<SpeedCell, String> {
    let reqs =
        super::volatility::scenario_stream_for(cfg, "storm", p.load, p.steps, p.seed)?;
    let bal = make_balancer(BalancerKind::Probe, cfg, p.seed);
    let mut c = Coordinator::new(cfg.clone(), bal, p.seed);
    c.submit_all(reqs.iter().cloned());
    let t0 = Instant::now();
    let mut steps = 0usize;
    let mut control_us_hidden = 0.0f64;
    let mut control_us_exposed = 0.0f64;
    while steps < p.max_steps {
        match c.step().map_err(|e| e.to_string())? {
            Some(rep) => {
                steps += 1;
                control_us_hidden += rep.control_us_hidden;
                control_us_exposed += rep.control_us_exposed;
            }
            None => break,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(SpeedCell {
        submitted: reqs.len(),
        completed: c
            .metrics
            .requests
            .iter()
            .filter(|m| m.finished.is_some())
            .count(),
        steps,
        wall,
        control_us_hidden,
        control_us_exposed,
    })
}

/// Run the sweep and emit `bench_results/BENCH_speed.json`.
pub fn run(p: &SpeedParams) -> BenchSet {
    let mut b = BenchSet::new(
        "BENCH_speed",
        &[
            "ranks",
            "mode",
            "requests",
            "completed",
            "steps",
            "steps_per_s",
            "planner_us_per_step",
            "control_us_exposed",
            "overlap_eff",
            "wall_ms",
        ],
    );
    if let Some(&r0) = p.ranks.first() {
        b.set_meta(super::bench_meta(&speed_cfg(p, r0), "speed"));
    }
    for &ranks in &p.ranks {
        let cfg = speed_cfg(p, ranks);
        let plan_s = planner_secs_per_plan(&cfg, p.plans, p.seed ^ ranks as u64);
        for pipelined in [false, true] {
            let mut cfg = cfg.clone();
            cfg.perf.pipeline_control = pipelined;
            let mode = if pipelined { "pipelined" } else { "sync" };
            let cell = match run_serving_cell(p, &cfg) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("speed cell at {ranks} ranks ({mode}) failed: {e}");
                    continue;
                }
            };
            b.row(&[
                ranks.to_string(),
                mode.to_string(),
                cell.submitted.to_string(),
                cell.completed.to_string(),
                cell.steps.to_string(),
                format!("{:.1}", cell.steps_per_sec()),
                format!("{:.1}", plan_s * 1e6 * SIM_LAYERS as f64),
                format!("{:.1}", cell.control_us_exposed_per_step()),
                format!("{:.3}", cell.overlap_efficiency()),
                format!("{:.1}", cell.wall * 1e3),
            ]);
        }
    }
    b.note(&format!(
        "storm preset, load {:.0}% of decode capacity, horizon {} steps, \
         {} sim layers, batch/rank {}, probe balancer",
        p.load * 100.0,
        p.steps,
        SIM_LAYERS,
        p.batch_per_rank
    ));
    b.note("steps_per_s = wall-clock serving-loop throughput (host-dependent;");
    b.note("CI diffs vs the cached rolling BENCH_speed_baseline at +/-15%, advisory only)");
    b.note(&format!(
        "planner_us_per_step = {} layers x mean plan_fabric_with wall-clock",
        SIM_LAYERS
    ));
    b.note("mode = control plane: sync (inline, default) vs pipelined (perf.pipeline_control)");
    b.note("control_us_exposed = mean control wall-clock the step loop blocked on, per step");
    b.note("overlap_eff = hidden / (hidden + exposed) control wall-clock (sync mode: 0)");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SpeedParams {
        SpeedParams {
            ranks: vec![8, 16],
            steps: 30,
            load: 0.7,
            batch_per_rank: 1,
            plans: 6,
            max_steps: 3_000,
            seed: 5,
        }
    }

    #[test]
    fn speed_bench_emits_all_rank_points() {
        let p = small();
        let b = run(&p);
        assert_eq!(b.rows.len(), 4, "sync + pipelined row per rank count");
        for (i, row) in b.rows.iter().enumerate() {
            let mode = &row[1];
            assert_eq!(
                mode,
                if i % 2 == 0 { "sync" } else { "pipelined" },
                "{row:?}: unexpected mode ordering"
            );
            let steps: usize = row[4].parse().unwrap();
            let sps: f64 = row[5].parse().unwrap();
            let plan_us: f64 = row[6].parse().unwrap();
            let ctrl_us: f64 = row[7].parse().unwrap();
            let eff: f64 = row[8].parse().unwrap();
            assert!(steps > 0, "{row:?}: no steps ran");
            assert!(sps > 0.0, "{row:?}: zero throughput");
            assert!(plan_us > 0.0 && plan_us.is_finite(), "{row:?}");
            assert!(ctrl_us >= 0.0 && ctrl_us.is_finite(), "{row:?}");
            assert!((0.0..=1.0).contains(&eff), "{row:?}: bad overlap_eff");
            if mode == "sync" {
                assert_eq!(eff, 0.0, "{row:?}: sync mode must expose all control time");
                assert!(ctrl_us > 0.0, "{row:?}: sync mode ran no planner?");
            }
        }
    }

    #[test]
    fn pipelined_cell_hides_control_time() {
        let p = small();
        let mut cfg = speed_cfg(&p, 8);
        cfg.perf.pipeline_control = true;
        let cell = run_serving_cell(&p, &cfg).expect("pipelined cell");
        assert!(cell.steps > 0);
        assert!(
            cell.control_us_hidden > 0.0,
            "pipeline hid no control time: {cell:?}"
        );
        assert!(cell.overlap_efficiency() > 0.0);
    }

    #[test]
    fn planner_microbench_positive_and_scales() {
        let p = small();
        let c8 = speed_cfg(&p, 8);
        let t8 = planner_secs_per_plan(&c8, 4, 3);
        assert!(t8 > 0.0 && t8.is_finite());
    }

    #[test]
    fn storm_run_completes_at_128_ranks() {
        // the acceptance smoke: a 128-rank storm cell must finish
        let mut p = small();
        p.ranks = vec![128];
        p.steps = 10;
        let cfg = speed_cfg(&p, 128);
        let cell = run_serving_cell(&p, &cfg).expect("128-rank cell");
        assert!(cell.steps > 0 && cell.submitted > 0);
    }
}
