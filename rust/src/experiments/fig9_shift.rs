//! Fig. 9: robustness to abrupt semantic shifts.
//!
//! Decode starts on *Code*; at step ≈200 the workload switches to
//! *Chinese* (higher IR). DeepSeek-EPLB: suboptimal until its warm-up
//! (~step 110) triggers a rebalance (visible jump), then degrades after
//! the shift because the placement is stale. PROBE: stable throughout —
//! the lookahead predictor adapts instantly.

use crate::config::BalancerKind;
use crate::coordinator::Coordinator;
use crate::util::bench::BenchSet;
use crate::workload::{Dataset, RequestGenerator, WorkloadSpec};

use super::{layer_scale, make_balancer, sim_config, SIM_LAYERS};

/// Fig. 9 measurement parameters.
pub struct Fig9Params {
    /// Decode steps per trace.
    pub steps: usize,
    /// Step at which the semantic shift lands.
    pub shift_at: usize,
    /// Decode tokens per rank.
    pub batch_per_rank: usize,
    /// Simulation seed.
    pub seed: u64,
    /// Report throughput averaged over windows of this many steps.
    pub window: usize,
}

impl Default for Fig9Params {
    fn default() -> Self {
        Fig9Params {
            steps: 400,
            shift_at: 200,
            batch_per_rank: 768,
            seed: 29,
            window: 25,
        }
    }
}

/// Throughput trace for one system (tokens/s per window).
pub fn trace(kind: BalancerKind, p: &Fig9Params) -> Vec<f64> {
    let mut cfg = sim_config("gpt-oss-120b");
    let scale = layer_scale(&cfg);
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = p.batch_per_rank;
    let bal = make_balancer(kind, &cfg, p.seed);
    let mut c = Coordinator::new(cfg.clone(), bal, p.seed);

    // requests cycle fast enough that new admissions after the shift pick
    // the new dataset
    let mut spec = WorkloadSpec::new(Dataset::Code, 4);
    spec.mean_prompt_len = 8;
    spec.mean_new_tokens = 40;
    let total_requests = cfg.global_batch() * (p.steps / 20 + 4);
    let mut g = RequestGenerator::new(spec, p.seed ^ 0x9)
        .shift_after((total_requests / 2) as u64, Dataset::Chinese);
    // enough queued requests to keep slots full; the dataset shift lands
    // mid-stream as old requests retire
    for r in g.take(total_requests) {
        c.submit(r);
    }

    let mut out = Vec::new();
    let mut win_tokens = 0usize;
    let mut win_time = 0.0;
    for step in 0..p.steps {
        // hard semantic shift of the underlying affinities at shift_at
        if step == p.shift_at {
            c.executor.routing_model.drift = 1.0;
        } else if step == p.shift_at + 1 {
            c.executor.routing_model.drift = 0.04;
        }
        match c.decode_step() {
            Some(o) => {
                win_tokens += c.active_count().max(1);
                win_time += o.latency * scale;
            }
            None => break,
        }
        if (step + 1) % p.window == 0 && win_time > 0.0 {
            out.push(win_tokens as f64 / win_time);
            win_tokens = 0;
            win_time = 0.0;
        }
    }
    out
}

/// Regenerate the Fig. 9 semantic-shift table.
pub fn run(p: &Fig9Params) -> BenchSet {
    let mut b = BenchSet::new(
        "fig9_semantic_shift",
        &["window_end_step", "sglang", "eplb", "probe"],
    );
    b.set_meta(super::bench_meta(&sim_config("gpt-oss-120b"), "fig9_shift"));
    let t_static = trace(BalancerKind::StaticEp, p);
    let t_eplb = trace(BalancerKind::Eplb, p);
    let t_probe = trace(BalancerKind::Probe, p);
    let n = t_static.len().min(t_eplb.len()).min(t_probe.len());
    for i in 0..n {
        b.row(&[
            ((i + 1) * p.window).to_string(),
            format!("{:.0}", t_static[i]),
            format!("{:.0}", t_eplb[i]),
            format!("{:.0}", t_probe[i]),
        ]);
    }
    b.note(&format!(
        "Code -> Chinese shift at step {} (affinity redraw)",
        p.shift_at
    ));
    b.note("paper: EPLB jumps after warm-up (~step 110), degrades after the");
    b.note("shift (stale placement); PROBE stays stable with no warm-up");
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn small() -> Fig9Params {
        Fig9Params {
            steps: 160,
            shift_at: 80,
            batch_per_rank: 256,
            seed: 4,
            window: 20,
        }
    }

    #[test]
    fn probe_stable_across_shift() {
        let p = small();
        let t = trace(BalancerKind::Probe, &p);
        assert!(t.len() >= 6);
        let before = mean(&t[1..t.len() / 2]);
        let after = mean(&t[t.len() / 2..]);
        // PROBE adapts instantly: no sustained collapse after the shift
        assert!(
            after > before * 0.85,
            "probe collapsed after shift: {before} -> {after}"
        );
    }

    #[test]
    fn probe_beats_eplb_after_shift() {
        let p = small();
        let te = trace(BalancerKind::Eplb, &p);
        let tp = trace(BalancerKind::Probe, &p);
        let n = te.len().min(tp.len());
        let half = n / 2;
        let eplb_after = mean(&te[half..n]);
        let probe_after = mean(&tp[half..n]);
        assert!(
            probe_after > eplb_after,
            "after shift: probe {probe_after} <= eplb {eplb_after}"
        );
    }
}
