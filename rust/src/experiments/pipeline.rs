//! `probe bench pipeline` — control-pipeline performance trajectory.
//!
//! Emits `bench_results/BENCH_pipeline.json` with the numbers that must
//! not regress as the control plane grows (ISSUE 2 satellite):
//! * planner wall-clock per invocation and per greedy iteration (the
//!   incremental [`crate::planner::LatencyState`] hot path);
//! * predictor fidelity (statistical calibration + causal transition
//!   model at depth 1);
//! * mean decode-step latency and fetch volume per lookahead depth.

use crate::config::{BalancerKind, ProbeConfig};
use crate::coordinator::Coordinator;
use crate::placement::Placement;
use crate::planner;
use crate::predictor::{fidelity, StatisticalPredictor};
use crate::routing::RoutingModel;
use crate::util::bench::{time_it, BenchSet};
use crate::workload::{Dataset, RequestGenerator, WorkloadSpec};

use super::{fig10_fidelity, sim_config, SIM_LAYERS};

/// Pipeline-bench parameters.
pub struct PipelineParams {
    /// Decode steps per lookahead-depth run.
    pub steps: usize,
    /// Tokens per planner/predictor micro-benchmark step.
    pub tokens: usize,
    /// Bench seed.
    pub seed: u64,
}

impl Default for PipelineParams {
    fn default() -> Self {
        PipelineParams {
            steps: 24,
            tokens: 6144,
            seed: 47,
        }
    }
}

/// Run the control-pipeline bench → `bench_results/BENCH_pipeline.json`.
pub fn run(p: &PipelineParams) -> BenchSet {
    let mut b = BenchSet::new("BENCH_pipeline", &["metric", "value", "unit"]);
    b.set_meta(super::bench_meta(&sim_config("gpt-oss-120b"), "pipeline"));

    // --- planner micro-benchmark ---
    let model = crate::model::MoeModel::gpt_oss_120b();
    let hw = crate::topology::HardwareProfile::hopper_141();
    let mut rm = RoutingModel::calibrated(1, model.n_experts, model.top_k, 3, p.seed);
    let routing = rm.route_step(&vec![0u16; p.tokens]).layers.remove(0);
    let counts = routing.expert_counts_by_source_f64(8);
    let base = Placement::sharded(8, model.n_experts, 3);
    let cfg = ProbeConfig::default();
    let windows = vec![1.0; 8];
    let mut iters = 0usize;
    let s = time_it(3, 20, || {
        let out = planner::plan(&counts, &base, &model, &hw, &windows, &cfg);
        iters = out.iterations.max(1);
        std::hint::black_box(&out);
    });
    b.row(&[
        "planner_us_per_plan".into(),
        format!("{:.1}", s.mean * 1e6),
        "us".into(),
    ]);
    b.row(&[
        "planner_us_per_iter".into(),
        format!("{:.2}", s.mean * 1e6 / iters as f64),
        "us".into(),
    ]);
    b.row(&["planner_iterations".into(), format!("{iters}"), "count".into()]);

    // --- predictor fidelity ---
    let mut sp = StatisticalPredictor::distilled(p.seed);
    let f = fidelity(&routing, &sp.predict(&routing));
    b.row(&[
        "statistical_topk_accuracy".into(),
        format!("{:.3}", f.top_k_accuracy),
        "fraction".into(),
    ]);
    let fig10p = fig10_fidelity::Fig10Params {
        artifacts_dir: "/nonexistent".into(),
        tokens: p.tokens.min(4096),
        seed: p.seed,
    };
    let (by_depth, stat_fid) = fig10_fidelity::transition_fidelity(&fig10p, 15);
    for (depth, cf) in by_depth {
        b.row(&[
            format!("transition_count_fidelity_d{depth}"),
            format!("{:.3}", cf),
            "fraction".into(),
        ]);
    }
    // anchor: the distilled error process measured on the SAME held-out
    // step as the transition rows (comparable by construction)
    b.row(&[
        "statistical_count_fidelity".into(),
        format!("{:.3}", stat_fid),
        "fraction".into(),
    ]);

    // --- end-to-end step latency per lookahead depth ---
    for depth in [1usize, 2, 4] {
        let mut cfg = sim_config("gpt-oss-120b");
        cfg.model.n_layers = SIM_LAYERS;
        cfg.batch_per_rank = 768;
        cfg.probe.lookahead_depth = depth;
        let bal = Box::new(crate::balancers::Probe::new(&cfg, cfg.probe.clone(), p.seed));
        let mut c = Coordinator::new(cfg.clone(), bal, p.seed);
        let mut spec = WorkloadSpec::new(Dataset::Repeat, 4);
        spec.mean_prompt_len = 8;
        spec.mean_new_tokens = p.steps * 2;
        let mut g = RequestGenerator::new(spec, p.seed ^ 5);
        for r in g.take(cfg.global_batch() + 16) {
            c.submit(r);
        }
        let outs = c.run_decode_steps(p.steps);
        let lat: Vec<f64> = outs.iter().map(|o| o.latency).collect();
        let fetches: usize = outs.iter().map(|o| o.prefetch_slots_total).sum();
        let exposed: f64 = outs.iter().map(|o| o.total_exposed()).sum();
        b.row(&[
            format!("step_latency_mean_L{depth}"),
            format!("{:.1}", crate::util::stats::mean(&lat) * 1e6),
            "us".into(),
        ]);
        b.row(&[
            format!("fetch_slots_L{depth}"),
            format!("{fetches}"),
            "count".into(),
        ]);
        b.row(&[
            format!("exposed_us_L{depth}"),
            format!("{:.1}", exposed * 1e6),
            "us".into(),
        ]);
    }
    // --- four-way balancer step latency on the identical workload ---
    for kind in BalancerKind::ALL {
        let mut cfg = sim_config("gpt-oss-120b");
        cfg.model.n_layers = SIM_LAYERS;
        cfg.batch_per_rank = 768;
        let bal = super::make_balancer(kind, &cfg, p.seed);
        let mut c = Coordinator::new(cfg.clone(), bal, p.seed);
        let mut spec = WorkloadSpec::new(Dataset::Repeat, 4);
        spec.mean_prompt_len = 8;
        spec.mean_new_tokens = p.steps * 2;
        let mut g = RequestGenerator::new(spec, p.seed ^ 5);
        for r in g.take(cfg.global_batch() + 16) {
            c.submit(r);
        }
        let outs = c.run_decode_steps(p.steps);
        let lat: Vec<f64> = outs.iter().map(|o| o.latency).collect();
        b.row(&[
            format!("step_latency_mean_{}", kind.name()),
            format!("{:.1}", crate::util::stats::mean(&lat) * 1e6),
            "us".into(),
        ]);
    }
    b.note("Repeat dataset, GPT-OSS, ep=8, b=768/rank; planner timed on");
    b.note("a fresh (cleared) base so µs/iter covers full greedy work");
    b.note("step_latency_mean_<balancer>: four-way arm on the identical stream");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_bench_emits_all_metric_families() {
        let p = PipelineParams {
            steps: 6,
            tokens: 2048,
            seed: 1,
        };
        let b = run(&p);
        for needle in [
            "planner_us_per_iter",
            "statistical_topk_accuracy",
            "transition_count_fidelity_d1",
            "step_latency_mean_L1",
            "fetch_slots_L4",
            "step_latency_mean_static",
            "step_latency_mean_eplb",
            "step_latency_mean_harmoeny",
            "step_latency_mean_probe",
        ] {
            assert!(
                b.rows.iter().any(|r| r[0] == needle),
                "missing metric {needle}"
            );
        }
        // the planner must stay well inside the paper's ~50µs plan budget
        // scale; allow slack for debug builds
        let per_plan: f64 = b
            .rows
            .iter()
            .find(|r| r[0] == "planner_us_per_plan")
            .unwrap()[1]
            .parse()
            .unwrap();
        assert!(per_plan > 0.0);
    }
}
