//! Experiment harnesses: one module per paper table/figure.
//!
//! Each `run_*` regenerates the corresponding figure's rows (same series,
//! simulator-scale numbers) as a [`BenchSet`], shared by the `cargo
//! bench` targets and the `probe bench` CLI. See DESIGN.md for the
//! per-experiment index and EXPERIMENTS.md for recorded results.
//!
//! Simulation-scale note: paper-scale models have 36–93 MoE layers; the
//! layers are statistically exchangeable in the routing model, so
//! experiments simulate `SIM_LAYERS` representative layers and scale
//! per-step latency by `n_layers / SIM_LAYERS` (recorded in every table's
//! notes).

pub mod ablations;
pub mod capacity;
pub mod disagg;
pub mod fabric;
pub mod fig10_fidelity;
pub mod fleet;
pub mod memory;
pub mod pipeline;
pub mod speed;
pub mod volatility;
pub mod fig11_timeline;
pub mod fig2_ir;
pub mod fig3_compute;
pub mod fig5_alltoall;
pub mod fig7_prefill;
pub mod fig8_pareto;
pub mod fig9_shift;

/// Representative MoE layers simulated per step (see module docs).
pub const SIM_LAYERS: usize = 6;

use crate::balancers::{Balancer, Eplb, HarMoEny, Probe, StaticEp};
use crate::config::{BalancerKind, Config, EplbConfig, ProbeConfig};
use crate::util::bench::BenchMeta;

/// Bench-result JSON schema version (bump on layout changes).
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// Provenance header for a bench table produced under `cfg`: schema
/// version, config content hash, preset label, EP ranks, and the wall
/// date from the `PROBE_BENCH_DATE` env var (empty when unset, so
/// hermetic CI replays stay bit-identical).
pub fn bench_meta(cfg: &Config, preset: &str) -> BenchMeta {
    BenchMeta {
        schema_version: BENCH_SCHEMA_VERSION,
        config_hash: cfg.content_hash(),
        preset: preset.to_string(),
        ranks: cfg.cluster.ep,
        date: std::env::var("PROBE_BENCH_DATE").unwrap_or_default(),
    }
}

/// Instantiate a balancer by kind with the experiment's config.
pub fn make_balancer(kind: BalancerKind, cfg: &Config, seed: u64) -> Box<dyn Balancer> {
    match kind {
        BalancerKind::StaticEp => Box::new(StaticEp::new(cfg)),
        BalancerKind::Eplb => Box::new(Eplb::new(cfg, cfg.eplb.clone())),
        BalancerKind::HarMoEny => Box::new(HarMoEny::new(cfg)),
        BalancerKind::Probe => Box::new(Probe::new(cfg, cfg.probe.clone(), seed)),
    }
}

/// Scale a simulated per-step latency from `SIM_LAYERS` to the model's
/// real depth.
pub fn layer_scale(cfg: &Config) -> f64 {
    cfg.model.n_layers as f64 / SIM_LAYERS as f64
}

/// Build an experiment config with the simulated layer count.
pub fn sim_config(model_name: &str) -> Config {
    let mut cfg = Config::default();
    cfg.model = crate::model::MoeModel::by_name(model_name).expect("model preset");
    cfg
}

/// Default PROBE knobs shared by experiments (paper §6.1).
pub fn experiment_probe_cfg() -> ProbeConfig {
    ProbeConfig::default()
}
/// Default EPLB knobs shared by experiments (paper §6.1).
pub fn experiment_eplb_cfg() -> EplbConfig {
    EplbConfig::default()
}
