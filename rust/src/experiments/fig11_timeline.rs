//! Fig. 11: timeline breakdown of a single decoding step.
//!
//! GPT-OSS, ep=8, b=768/rank, averaged over layers 1..L (layer 0
//! excluded, as in the paper). Top: baseline (static EP) — Combine is
//! inflated by straggler synchronization. Bottom: PROBE's dual track —
//! predict/plan hidden behind Dispatch, prefetch (≤3 experts) split-phase
//! hidden behind MoE compute + next Attention. Paper numbers: IR
//! 2.13→1.09, compute skew (max/avg) 2.27→1.18.

use crate::balancers::decide_step;
use crate::config::BalancerKind;
use crate::metrics::Phase;
use crate::simulator::ClusterSim;
use crate::util::bench::BenchSet;
use crate::util::stats::mean;

use super::{make_balancer, sim_config};

/// Fig. 11 measurement parameters.
pub struct Fig11Params {
    /// Decode tokens per rank.
    pub batch_per_rank: usize,
    /// MoE layers simulated per step.
    pub layers: usize,
    /// Warm-up steps before the measured step.
    pub warm_steps: usize,
    /// Simulation seed.
    pub seed: u64,
}

impl Default for Fig11Params {
    fn default() -> Self {
        Fig11Params {
            batch_per_rank: 768,
            layers: 12, // averaged layers (paper: 35); 12 keeps it quick
            warm_steps: 3,
            seed: 37,
        }
    }
}

/// One system's measured timeline breakdown.
pub struct TimelineResult {
    /// Mean main-track phase durations (layers 1..).
    pub phases: Vec<(Phase, f64)>,
    /// Mean aux-track phase durations (layers 1..).
    pub aux_phases: Vec<(Phase, f64)>,
    /// Mean token-load IR (layers 1..).
    pub mean_ir: f64,
    /// Mean compute skew (layers 1..).
    pub mean_comp_skew: f64,
    /// Total exposed transfer of the measured step.
    pub exposed: f64,
    /// Measured step latency.
    pub step_latency: f64,
}

/// Measure one balancer's warmed dual-track timeline.
pub fn measure(kind: BalancerKind, p: &Fig11Params) -> TimelineResult {
    let mut cfg = sim_config("gpt-oss-120b");
    cfg.model.n_layers = p.layers;
    cfg.batch_per_rank = p.batch_per_rank;
    let mut bal = make_balancer(kind, &cfg, p.seed);
    let mut sim = ClusterSim::new(cfg.model.clone(), cfg.cluster.clone());
    let mut rm = crate::routing::RoutingModel::calibrated(
        p.layers,
        cfg.model.n_experts,
        cfg.model.top_k,
        4,
        p.seed,
    );
    let tokens = cfg.global_batch();
    // warm the balancer (EMA windows, history)
    let mut outcome = None;
    for step in 0..=p.warm_steps {
        let domains: Vec<u16> = (0..tokens).map(|i| (i % 3) as u16).collect();
        let routing = rm.route_step(&domains);
        let ds = decide_step(bal.as_mut(), step, &routing);
        outcome = Some(sim.run_step(&routing, &ds));
        rm.step_drift();
    }
    let out = outcome.unwrap();
    let phases = ClusterSim::phase_breakdown(&out, true);
    // aux phases (mean over layers 1..)
    let aux_of = |ph: Phase| -> f64 {
        mean(
            &out.timelines[1..]
                .iter()
                .map(|tl| {
                    tl.aux
                        .iter()
                        .filter(|s| s.phase == ph)
                        .map(|s| s.dur())
                        .sum::<f64>()
                })
                .collect::<Vec<_>>(),
        )
    };
    TimelineResult {
        phases,
        aux_phases: vec![
            (Phase::Predict, aux_of(Phase::Predict)),
            (Phase::Plan, aux_of(Phase::Plan)),
            (Phase::Prefetch, aux_of(Phase::Prefetch)),
            (Phase::Update, aux_of(Phase::Update)),
        ],
        mean_ir: mean(&out.ir_per_layer[1..]),
        mean_comp_skew: mean(&out.comp_skew_per_layer[1..]),
        exposed: out.timelines.iter().map(|t| t.exposed_overhead).sum(),
        step_latency: out.latency,
    }
}

/// Regenerate the Fig. 11 timeline-breakdown table.
pub fn run(p: &Fig11Params) -> BenchSet {
    let mut b = BenchSet::new(
        "fig11_timeline_breakdown",
        &["system", "phase", "track", "mean_us"],
    );
    b.set_meta(super::bench_meta(
        &sim_config("gpt-oss-120b"),
        "fig11_timeline",
    ));
    for (kind, name) in [
        (BalancerKind::StaticEp, "baseline"),
        (BalancerKind::Probe, "probe"),
    ] {
        let r = measure(kind, p);
        for (ph, d) in &r.phases {
            b.row(&[
                name.into(),
                ph.name().into(),
                "main".into(),
                format!("{:.1}", d * 1e6),
            ]);
        }
        for (ph, d) in &r.aux_phases {
            if *d > 0.0 {
                b.row(&[
                    name.into(),
                    ph.name().into(),
                    "aux".into(),
                    format!("{:.1}", d * 1e6),
                ]);
            }
        }
        b.row(&[
            name.into(),
            "IR".into(),
            "metric".into(),
            format!("{:.2}", r.mean_ir),
        ]);
        b.row(&[
            name.into(),
            "comp_skew".into(),
            "metric".into(),
            format!("{:.2}", r.mean_comp_skew),
        ]);
        b.row(&[
            name.into(),
            "exposed_overhead".into(),
            "metric".into(),
            format!("{:.1}", r.exposed * 1e6),
        ]);
        b.row(&[
            name.into(),
            "step_latency".into(),
            "metric".into(),
            format!("{:.1}", r.step_latency * 1e6),
        ]);
    }
    b.note("paper: IR 2.13 -> 1.09; compute skew 2.27 -> 1.18; all control");
    b.note("overheads hidden; Combine shrinks via eliminated sync waits");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Fig11Params {
        Fig11Params {
            batch_per_rank: 512,
            layers: 6,
            warm_steps: 2,
            seed: 2,
        }
    }

    #[test]
    fn probe_cuts_ir_and_skew() {
        let p = small();
        let base = measure(BalancerKind::StaticEp, &p);
        let probe = measure(BalancerKind::Probe, &p);
        assert!(base.mean_ir > 1.3, "baseline IR too low: {}", base.mean_ir);
        assert!(
            probe.mean_ir < base.mean_ir - 0.15,
            "IR {} -> {}",
            base.mean_ir,
            probe.mean_ir
        );
        assert!(probe.mean_comp_skew < base.mean_comp_skew);
        assert!(probe.step_latency < base.step_latency);
    }

    #[test]
    fn sync_wait_shrinks_under_probe() {
        let p = small();
        let base = measure(BalancerKind::StaticEp, &p);
        let probe = measure(BalancerKind::Probe, &p);
        let wait = |r: &TimelineResult| {
            r.phases
                .iter()
                .find(|(ph, _)| *ph == Phase::SyncWait)
                .map(|(_, d)| *d)
                .unwrap_or(0.0)
        };
        assert!(
            wait(&probe) < wait(&base),
            "sync wait {} -> {}",
            wait(&base),
            wait(&probe)
        );
    }

    #[test]
    fn probe_overheads_fully_hidden() {
        let p = small();
        let probe = measure(BalancerKind::Probe, &p);
        assert_eq!(probe.exposed, 0.0, "exposed overhead must be zero");
        // aux phases exist (predict/plan/prefetch visible on aux track)
        assert!(probe.aux_phases.iter().any(|(_, d)| *d > 0.0));
    }
}
