//! `probe bench disagg` — colocated vs disaggregated prefill/decode
//! serving at matched offered load.
//!
//! For each scenario preset the same calibrated request stream (see
//! [`super::volatility`] for the self-calibration scheme) is served
//! twice on the same replica count:
//!
//! * **colocated** — [`crate::server::fleet::run_fleet`] under JSQ
//!   dispatch: every replica runs the unified continuous-batching step,
//!   so prefill chunks ride in decode steps and inflate TPOT;
//! * **disagg** — [`crate::server::disagg::run_disagg`]: dedicated
//!   prefill/decode pools, KV handoff as routed flows on the
//!   inter-replica fabric, SLO-aware admission, backlog-driven role
//!   re-balancing.
//!
//! Reported per cell: decode throughput, TTFT/TPOT percentiles (disagg
//! TTFT *includes* the KV transfer), KV bytes shipped, exposed transfer
//! time, deferral and re-balance counts →
//! `bench_results/BENCH_disagg.json`.

use crate::config::{BalancerKind, Config};
use crate::engine::sim::SimExecutor;
use crate::engine::ServingEngine;
use crate::server::disagg::{run_disagg, DisaggReport, DisaggRunConfig};
use crate::server::dispatch::DispatchKind;
use crate::server::fleet::{run_fleet, FleetConfig, FleetReport};
use crate::util::bench::BenchSet;
use crate::workload::{Request, Scenario, ScenarioGenerator};

use super::volatility::{build_scenario_for, calibrate_step_latency_for};
use super::{make_balancer, SIM_LAYERS};

/// Sweep parameters.
pub struct DisaggParams {
    /// Scenario presets to run (default: the three the paper-style
    /// comparison needs — steady, burst, multi_tenant).
    pub presets: Vec<String>,
    /// Balancers driving every replica engine (both modes use the same
    /// balancer per cell, so the colocated/disagg comparison isolates
    /// the serving topology).
    pub balancers: Vec<BalancerKind>,
    /// Replicas per serving mode (split across roles under disagg).
    pub replicas: usize,
    /// Offered load as a fraction of calibrated decode capacity.
    pub load: f64,
    /// Scenario horizon in decode-step units.
    pub steps: usize,
    /// Decode tokens per rank (kept small so queueing is visible).
    pub batch_per_rank: usize,
    /// Mean prompt length of the base tenant (the stream is reshaped
    /// prefill-heavy so the colocated interference is visible).
    pub mean_prompt: usize,
    /// Mean decode budget per request (tokens).
    pub mean_new_tokens: usize,
    /// Safety cap on steps per replica.
    pub max_steps: usize,
    /// Root seed (streams and engines derive from it).
    pub seed: u64,
}

impl Default for DisaggParams {
    fn default() -> Self {
        DisaggParams {
            presets: vec!["steady".into(), "burst".into(), "multi_tenant".into()],
            balancers: BalancerKind::ALL.to_vec(),
            replicas: 4,
            load: 0.7,
            steps: 160,
            batch_per_rank: 2,
            mean_prompt: 384,
            mean_new_tokens: 24,
            max_steps: 200_000,
            seed: 41,
        }
    }
}

/// Serving config for both modes: small decode batch, a prefill chunk
/// small enough that long prompts span many chunked steps — the regime
/// where colocated prefill visibly stretches decode steps.
pub fn disagg_cfg(p: &DisaggParams) -> Config {
    let mut cfg = Config::default();
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = p.batch_per_rank;
    cfg.prefill_chunk_per_rank = 64;
    cfg
}

/// Reshape the calibrated scenario's tenants into a mixed
/// prompt-length population ([`build_scenario_for`] pins prompts to 16
/// tokens, which would make KV handoffs trivial): tenant *i* cycles
/// through {base, prompt-heavy, decode-heavy} shapes around
/// `mean_prompt`/`mean_new_tokens`.
fn shape_tenants(s: &mut Scenario, mean_prompt: usize, mean_new_tokens: usize) {
    for (i, t) in s.tenants.iter_mut().enumerate() {
        match i % 3 {
            0 => {
                t.spec.mean_prompt_len = mean_prompt;
                t.spec.mean_new_tokens = mean_new_tokens;
            }
            1 => {
                t.spec.mean_prompt_len = mean_prompt * 2;
                t.spec.mean_new_tokens = (mean_new_tokens / 2).max(4);
            }
            _ => {
                t.spec.mean_prompt_len = (mean_prompt / 2).max(8);
                t.spec.mean_new_tokens = mean_new_tokens * 2;
            }
        }
    }
}

/// The identical calibrated stream both modes serve for one preset.
pub fn stream_for(p: &DisaggParams, preset: &str, idx: usize) -> Vec<Request> {
    let cfg = disagg_cfg(p);
    let t_step = calibrate_step_latency_for(&cfg, p.seed);
    let mut scenario =
        build_scenario_for(&cfg, preset, p.load, p.steps, p.mean_new_tokens, t_step)
            .unwrap_or_else(|| panic!("unknown scenario preset {preset:?}"));
    shape_tenants(&mut scenario, p.mean_prompt, p.mean_new_tokens);
    let stream_seed = p.seed ^ (idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    ScenarioGenerator::new(scenario, stream_seed).generate()
}

fn sim_factory(
    p: &DisaggParams,
    kind: BalancerKind,
) -> impl Fn(usize) -> anyhow::Result<ServingEngine<SimExecutor>> + Send + Sync + 'static {
    let cfg = disagg_cfg(p);
    let seed = p.seed;
    move |idx: usize| {
        let replica_seed = seed ^ (idx as u64).wrapping_mul(0x9E37_79B9);
        let bal = make_balancer(kind, &cfg, replica_seed);
        Ok(ServingEngine::new(cfg.clone(), bal, replica_seed))
    }
}

/// Serve one preset's stream in both modes under one balancer. Exposed
/// for integration tests (the burst TPOT-win gate in
/// `tests/disagg_handoff.rs`).
pub fn run_pair(
    p: &DisaggParams,
    preset: &str,
    idx: usize,
    kind: BalancerKind,
) -> (Vec<Request>, FleetReport, DisaggReport) {
    let reqs = stream_for(p, preset, idx);
    let cfg = disagg_cfg(p);
    let fleet_cfg = FleetConfig {
        replicas: p.replicas,
        policy: DispatchKind::ShortestQueue,
        max_steps: p.max_steps,
        threads: 0,
        parallel: true,
    };
    let colocated = run_fleet(&fleet_cfg, &reqs, sim_factory(p, kind));
    let t_step = calibrate_step_latency_for(&cfg, p.seed);
    let mut rc = DisaggRunConfig::from_config(p.replicas, &cfg);
    rc.max_steps = p.max_steps;
    // calibrated backlog-model rates: a decode step moves the global
    // batch, a prefill step moves a whole chunk
    let gb = cfg.global_batch().max(1) as f64;
    let chunk = (cfg.prefill_chunk_per_rank * cfg.cluster.ep).max(1) as f64;
    rc.service_rate = gb / t_step;
    rc.prefill_rate_ratio = (chunk / gb).max(1.0);
    let disagg = run_disagg(&rc, &reqs, sim_factory(p, kind));
    (reqs, colocated, disagg)
}

/// Run the full comparison and emit `bench_results/BENCH_disagg.json`.
pub fn run(p: &DisaggParams) -> BenchSet {
    let mut b = BenchSet::new(
        "BENCH_disagg",
        &[
            "scenario",
            "mode",
            "balancer",
            "replicas",
            "requests",
            "completed",
            "decode_tok_s",
            "ttft_p50_ms",
            "ttft_p99_ms",
            "tpot_p50_ms",
            "tpot_p99_ms",
            "kv_gb",
            "exposed_p99_ms",
            "deferred",
            "rebalances",
        ],
    );
    b.set_meta(super::bench_meta(
        &disagg_cfg(p),
        &p.presets.join(","),
    ));
    for (idx, preset) in p.presets.iter().enumerate() {
        for &kind in &p.balancers {
            let (reqs, colocated, disagg) = run_pair(p, preset, idx, kind);
            let cm = colocated.merged_metrics();
            let (cttft, ctpot) = (cm.ttft_summary(), cm.tpot_summary());
            b.row(&[
                preset.clone(),
                "colocated".to_string(),
                kind.name().to_string(),
                p.replicas.to_string(),
                reqs.len().to_string(),
                colocated.completed().to_string(),
                format!("{:.0}", colocated.aggregate_throughput()),
                format!("{:.2}", cttft.p50 * 1e3),
                format!("{:.2}", cttft.p99 * 1e3),
                format!("{:.3}", ctpot.p50 * 1e3),
                format!("{:.3}", ctpot.p99 * 1e3),
                "0.000".to_string(),
                "0.00".to_string(),
                "0".to_string(),
                "0".to_string(),
            ]);
            let (dttft, dtpot) = (disagg.ttft_summary(), disagg.tpot_summary());
            b.row(&[
                preset.clone(),
                "disagg".to_string(),
                kind.name().to_string(),
                p.replicas.to_string(),
                reqs.len().to_string(),
                disagg.completed().to_string(),
                format!("{:.0}", disagg.aggregate_throughput()),
                format!("{:.2}", dttft.p50 * 1e3),
                format!("{:.2}", dttft.p99 * 1e3),
                format!("{:.3}", dtpot.p50 * 1e3),
                format!("{:.3}", dtpot.p99 * 1e3),
                format!("{:.3}", disagg.kv_bytes / 1e9),
                format!("{:.2}", disagg.exposed_transfer.p99 * 1e3),
                disagg.deferred.to_string(),
                disagg.rebalances.to_string(),
            ]);
        }
    }
    b.note(&format!(
        "matched offered load per preset: identical calibrated stream served \
         colocated (fleet JSQ) and disaggregated ({} replicas, auto role split)",
        p.replicas
    ));
    b.note("disagg ttft includes KV transfer; kv_gb = bytes shipped over inter-replica rails");
    b.note(&format!(
        "prefill-heavy shaped tenants (mean prompt {}), load {:.0}% of decode capacity, \
         horizon {} steps",
        p.mean_prompt,
        p.load * 100.0,
        p.steps
    ));
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DisaggParams {
        DisaggParams {
            presets: vec!["steady".into()],
            balancers: vec![BalancerKind::StaticEp],
            replicas: 4,
            load: 0.6,
            steps: 40,
            batch_per_rank: 1,
            mean_prompt: 192,
            mean_new_tokens: 16,
            max_steps: 100_000,
            seed: 41,
        }
    }

    #[test]
    fn disagg_bench_emits_paired_cells() {
        let p = small();
        let b = run(&p);
        assert_eq!(b.rows.len(), 2, "one colocated + one disagg row");
        for row in &b.rows {
            assert_eq!(row[2], "static");
            let submitted: usize = row[4].parse().unwrap();
            let completed: usize = row[5].parse().unwrap();
            assert!(submitted > 0, "{row:?}: empty stream");
            assert_eq!(completed, submitted, "{row:?}: dropped requests");
            let tok_s: f64 = row[6].parse().unwrap();
            assert!(tok_s > 0.0, "{row:?}");
        }
        assert_eq!(b.rows[0][1], "colocated");
        assert_eq!(b.rows[1][1], "disagg");
        // the disagg row must ship real KV bytes over the fabric
        let kv_gb: f64 = b.rows[1][11].parse().unwrap();
        assert!(kv_gb > 0.0, "disagg run moved no KV");
    }

    #[test]
    fn both_modes_serve_the_identical_stream() {
        let p = small();
        let (reqs, colocated, disagg) = run_pair(&p, "steady", 0, BalancerKind::StaticEp);
        assert_eq!(colocated.completed(), reqs.len());
        assert_eq!(disagg.completed(), reqs.len());
        assert_eq!(disagg.kv_pages_freed, disagg.kv_pages_admitted);
        // deterministic: same pair again is bit-identical
        let (_, c2, d2) = run_pair(&p, "steady", 0, BalancerKind::StaticEp);
        assert_eq!(
            colocated.ttft_summary().p50.to_bits(),
            c2.ttft_summary().p50.to_bits()
        );
        assert_eq!(
            disagg.ttft_summary().p50.to_bits(),
            d2.ttft_summary().p50.to_bits()
        );
        assert_eq!(disagg.kv_bytes.to_bits(), d2.kv_bytes.to_bits());
        assert_eq!(disagg.role_timeline, d2.role_timeline);
    }
}
