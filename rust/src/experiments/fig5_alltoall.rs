//! Fig. 5: skew hurts All-to-All efficiency.
//!
//! Top: effective dispatch bandwidth — manually balanced top-k routing vs
//! real (semantically skewed) workloads. Bottom: max per-rank traffic
//! volume. Receiver hotspots collapse effective cluster bandwidth because
//! the collective synchronizes on the slowest rank.

use crate::model::MoeModel;
use crate::perfmodel::{comm_volumes, effective_bandwidth, Assignment, DispatchPlan};
use crate::placement::Placement;
use crate::routing::{LayerRouting, RoutingModel};
use crate::topology::HardwareProfile;
use crate::util::bench::BenchSet;
use crate::util::Rng;

/// Fig. 5 sweep parameters.
pub struct Fig5Params {
    /// Expert-parallel group size.
    pub ep: usize,
    /// Token counts swept.
    pub token_counts: Vec<usize>,
    /// Routing-model seed.
    pub seed: u64,
}

impl Default for Fig5Params {
    fn default() -> Self {
        Fig5Params {
            ep: 8,
            token_counts: vec![1024, 2048, 4096, 8192, 16384],
            seed: 11,
        }
    }
}

/// Manually balanced top-k baseline: round-robin experts → uniform load.
fn balanced_routing(tokens: usize, model: &MoeModel, seed: u64) -> LayerRouting {
    let mut rng = Rng::new(seed);
    let e = model.n_experts as u16;
    let mut experts = Vec::with_capacity(tokens * model.top_k);
    let mut cursor = 0u16;
    for _ in 0..tokens {
        // k distinct experts spread uniformly, randomized phase
        let start = cursor + (rng.next_below(4)) as u16;
        for j in 0..model.top_k as u16 {
            experts.push((start + j * (e / model.top_k as u16)) % e);
        }
        cursor = (cursor + 1) % e;
    }
    LayerRouting::new(tokens, model.top_k, model.n_experts, experts)
}

fn measure(routing: &LayerRouting, ep: usize, model: &MoeModel, hw: &HardwareProfile) -> (f64, f64) {
    let placement = Placement::sharded(ep, model.n_experts, 0);
    let a = Assignment::locality_first(routing, &placement);
    let plan = DispatchPlan::from_assignment(routing, &a);
    let vol = comm_volumes(routing, &plan, ep, model.token_bytes());
    (effective_bandwidth(&vol, hw), vol.max_critical())
}

/// Regenerate the Fig. 5 All-to-All-skew table.
pub fn run(p: &Fig5Params) -> BenchSet {
    let model = MoeModel::gpt_oss_120b();
    let hw = HardwareProfile::hopper_141();
    let mut meta_cfg = crate::config::Config::default();
    meta_cfg.model = model.clone();
    meta_cfg.cluster.ep = p.ep;
    let mut b = BenchSet::new(
        "fig5_alltoall_skew",
        &[
            "tokens",
            "balanced_bw_GBps",
            "real_bw_GBps",
            "bw_drop",
            "balanced_maxvol_MB",
            "real_maxvol_MB",
        ],
    );
    b.set_meta(super::bench_meta(&meta_cfg, "fig5_alltoall"));
    let mut rm = RoutingModel::calibrated(1, model.n_experts, model.top_k, 4, p.seed);
    for &tokens in &p.token_counts {
        let balanced = balanced_routing(tokens, &model, p.seed ^ tokens as u64);
        let real = rm.route_step(&vec![0u16; tokens]).layers.remove(0);
        let (bw_bal, vol_bal) = measure(&balanced, p.ep, &model, &hw);
        let (bw_real, vol_real) = measure(&real, p.ep, &model, &hw);
        b.row(&[
            tokens.to_string(),
            format!("{:.1}", bw_bal / 1e9),
            format!("{:.1}", bw_real / 1e9),
            format!("{:.2}x", bw_bal / bw_real.max(1e-9)),
            format!("{:.2}", vol_bal / 1e6),
            format!("{:.2}", vol_real / 1e6),
        ]);
    }
    b.note("paper (8xH800 + DeepEP): receiver hotspots inflate max per-rank");
    b.note("traffic and collapse effective bandwidth vs balanced top-k");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_workload_worse_than_balanced() {
        let p = Fig5Params {
            token_counts: vec![4096, 8192],
            ..Default::default()
        };
        let b = run(&p);
        for row in &b.rows {
            let bw_bal: f64 = row[1].parse().unwrap();
            let bw_real: f64 = row[2].parse().unwrap();
            let vol_bal: f64 = row[4].parse().unwrap();
            let vol_real: f64 = row[5].parse().unwrap();
            assert!(bw_real < bw_bal, "skew should reduce effective bw");
            assert!(vol_real > vol_bal, "skew should inflate max volume");
        }
    }

    #[test]
    fn balanced_routing_is_actually_balanced() {
        let model = MoeModel::gpt_oss_120b();
        let r = balanced_routing(4096, &model, 3);
        let counts = r.expert_counts();
        let loads: Vec<f64> = (0..8)
            .map(|rk| counts[rk * 16..(rk + 1) * 16].iter().sum::<u32>() as f64)
            .collect();
        let ir = crate::util::stats::imbalance_ratio(&loads);
        assert!(ir < 1.1, "balanced IR {ir}");
    }
}
