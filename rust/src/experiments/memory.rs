//! `probe bench memory` — memory-governance sweep (ISSUE 5).
//!
//! Runs {static, eplb, harmoeny, probe} × {short-ctx, long-ctx,
//! prefill-burst} on the memory-governed serving engine and reports TTFT/TPOT percentiles,
//! decode throughput, the preemption rate, and the replica-headroom
//! utilization (fraction of the policy's replica budget the per-rank
//! [`crate::placement::memory::MemoryManager`] could still grant,
//! averaged over steps) → `bench_results/BENCH_memory.json`.
//!
//! The pressured cells derive their per-rank HBM capacity from the
//! governor's own formulas (weights + activation reserve + a KV pool
//! sized to a *fraction* of the scenario's concurrent demand), so the
//! sweep is model-portable: long-ctx decode drains the replica headroom
//! as KV grows, prefill-burst adds the activation watermark of large
//! chunked prompts, short-ctx runs at the profile's full capacity as a
//! control. Streams use fixed per-request lengths so the pressure
//! fraction is exact. EPLB's static per-layer placeholders cost
//! `n_layers × W` per slot, so its headroom collapses first — the
//! paper's Fig. 7 exclusion measured live.

use crate::config::{BalancerKind, Config};
use crate::coordinator::Coordinator;
use crate::placement::memory::{activation_bytes, kv_bytes_per_token, weights_per_rank};
use crate::util::bench::BenchSet;
use crate::util::stats::Summary;
use crate::workload::{Dataset, Request};

use super::{make_balancer, SIM_LAYERS};

/// One memory scenario: fixed request shape plus how tight the KV pool
/// is relative to the concurrent demand.
#[derive(Debug, Clone)]
pub struct MemoryScenario {
    /// Cell label (`scenario` column).
    pub name: String,
    /// Prompt tokens per request (exact, not a distribution mean).
    pub prompt: usize,
    /// Decode tokens per request (exact).
    pub new_tokens: usize,
    /// KV pool as a fraction of the concurrent per-rank KV demand;
    /// `0.0` = run at the hardware profile's full capacity (control).
    pub pool_frac: f64,
}

impl MemoryScenario {
    /// The paper-motivated default cells: a short-context control,
    /// long-context decode (KV pressure), and a prefill-heavy burst
    /// (activation + KV pressure).
    pub fn presets() -> Vec<MemoryScenario> {
        vec![
            MemoryScenario {
                name: "short-ctx".into(),
                prompt: 64,
                new_tokens: 32,
                pool_frac: 0.0,
            },
            MemoryScenario {
                name: "long-ctx".into(),
                prompt: 4096,
                new_tokens: 512,
                pool_frac: 0.62,
            },
            MemoryScenario {
                name: "prefill-burst".into(),
                prompt: 8192,
                new_tokens: 16,
                pool_frac: 0.55,
            },
        ]
    }
}

/// Sweep parameters.
pub struct MemoryParams {
    /// Scenario cells to run.
    pub scenarios: Vec<MemoryScenario>,
    /// Balancers to compare.
    pub balancers: Vec<BalancerKind>,
    /// Requests per cell (identical stream per scenario across
    /// balancers).
    pub requests: usize,
    /// Decode tokens per rank (kept small so queueing is visible).
    pub batch_per_rank: usize,
    /// Chunked-prefill tokens per rank per step.
    pub chunk_per_rank: usize,
    /// Safety cap on steps per cell.
    pub max_steps: usize,
    /// Root seed (balancers derive from it).
    pub seed: u64,
}

impl Default for MemoryParams {
    fn default() -> Self {
        MemoryParams {
            scenarios: MemoryScenario::presets(),
            balancers: BalancerKind::ALL.to_vec(),
            requests: 48,
            batch_per_rank: 8,
            chunk_per_rank: 512,
            max_steps: 20_000,
            seed: 41,
        }
    }
}

/// Serving config for one scenario cell: SIM_LAYERS representative
/// layers, small decode batch, and — for pressured scenarios — a
/// per-rank HBM capacity derived from the governor's own formulas so
/// the KV pool holds only `pool_frac` of the concurrent demand (with a
/// floor of 1.15× one request, so a single request always fits and the
/// engine can make progress; pressure comes from concurrency).
pub fn scenario_cfg(s: &MemoryScenario, p: &MemoryParams) -> Config {
    let mut cfg = Config::default();
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = p.batch_per_rank;
    cfg.prefill_chunk_per_rank = p.chunk_per_rank;
    if s.pool_frac > 0.0 {
        let ep = cfg.cluster.ep;
        let rows_per_req = (s.prompt + s.new_tokens) as f64;
        let concurrency = p.requests.min(cfg.global_batch());
        let per_rank = (concurrency as f64 / ep as f64).ceil().max(1.0);
        let pool_rows = (s.pool_frac * per_rank * rows_per_req).max(1.15 * rows_per_req);
        let budget_tokens = cfg.global_batch() + cfg.prefill_chunk_per_rank * ep;
        let capacity = weights_per_rank(&cfg.model, ep)
            + activation_bytes(&cfg.model, budget_tokens.div_ceil(ep))
            + pool_rows * kv_bytes_per_token(&cfg.model);
        cfg.memory.hbm_capacity_gb = capacity / 1e9;
    }
    cfg
}

/// The scenario's closed-loop request stream: fixed lengths, maximal
/// semantic skew (the Repeat domain), identical across balancers.
pub fn scenario_stream(s: &MemoryScenario, p: &MemoryParams) -> Vec<Request> {
    (0..p.requests as u64)
        .map(|id| Request {
            id,
            tenant: 0,
            domain: 3, // Repeat collapses onto the last of 4 domains
            dataset: Dataset::Repeat,
            prompt_len: s.prompt,
            max_new_tokens: s.new_tokens,
            arrival: 0.0,
        })
        .collect()
}

/// Outcome of one (scenario, balancer) cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Requests submitted.
    pub submitted: usize,
    /// Requests completed within the step cap.
    pub completed: usize,
    /// Steps executed.
    pub steps: usize,
    /// Aggregate decode throughput (tokens/s).
    pub throughput: f64,
    /// TTFT distribution (seconds).
    pub ttft: Summary,
    /// TPOT distribution (seconds).
    pub tpot: Summary,
    /// Preemptions over the run.
    pub preemptions: usize,
    /// Preemptions per executed step.
    pub preempt_rate: f64,
    /// Mean fraction of the policy's replica budget still grantable
    /// (1.0 = full headroom, 0.0 = KV pressure exhausted it).
    pub headroom_util: f64,
}

/// Serve one scenario stream under one balancer and collect the cell
/// metrics.
pub fn run_cell(
    s: &MemoryScenario,
    p: &MemoryParams,
    kind: BalancerKind,
    reqs: &[Request],
) -> CellResult {
    let cfg = scenario_cfg(s, p);
    let bal = make_balancer(kind, &cfg, p.seed);
    let mut c = Coordinator::new(cfg, bal, p.seed);
    c.submit_all(reqs.iter().cloned());
    let max_slots = c.executor.memory.max_slots().max(1);
    let mut steps = 0usize;
    let mut headroom_acc = 0.0;
    while steps < p.max_steps {
        if c.decode_step().is_none() {
            break;
        }
        steps += 1;
        let caps = &c.executor.last_replica_caps;
        let granted: usize = caps.iter().map(|&x| x.min(max_slots)).sum();
        headroom_acc += granted as f64 / (caps.len().max(1) * max_slots) as f64;
    }
    CellResult {
        submitted: reqs.len(),
        completed: c
            .metrics
            .requests
            .iter()
            .filter(|m| m.finished.is_some())
            .count(),
        steps,
        throughput: c.metrics.throughput(),
        ttft: c.metrics.ttft_summary(),
        tpot: c.metrics.tpot_summary(),
        preemptions: c.metrics.preemptions,
        preempt_rate: c.metrics.preemptions as f64 / steps.max(1) as f64,
        headroom_util: headroom_acc / steps.max(1) as f64,
    }
}

/// Run the full sweep and emit `bench_results/BENCH_memory.json`.
pub fn run(p: &MemoryParams) -> BenchSet {
    let mut b = BenchSet::new(
        "BENCH_memory",
        &[
            "scenario",
            "balancer",
            "requests",
            "completed",
            "tok_s",
            "ttft_p50_ms",
            "ttft_p99_ms",
            "tpot_p50_ms",
            "preempt_rate",
            "headroom_util",
        ],
    );
    if let Some(s0) = p.scenarios.first() {
        b.set_meta(super::bench_meta(&scenario_cfg(s0, p), &s0.name));
    }
    for s in &p.scenarios {
        let reqs = scenario_stream(s, p);
        for &kind in &p.balancers {
            let cell = run_cell(s, p, kind, &reqs);
            b.row(&[
                s.name.clone(),
                kind.name().to_string(),
                cell.submitted.to_string(),
                cell.completed.to_string(),
                format!("{:.0}", cell.throughput),
                format!("{:.2}", cell.ttft.p50 * 1e3),
                format!("{:.2}", cell.ttft.p99 * 1e3),
                format!("{:.3}", cell.tpot.p50 * 1e3),
                format!("{:.4}", cell.preempt_rate),
                format!("{:.3}", cell.headroom_util),
            ]);
        }
    }
    b.note(&format!(
        "{} sim layers, batch/rank {}, chunk/rank {}, {} reqs/cell, identical stream per scenario",
        SIM_LAYERS, p.batch_per_rank, p.chunk_per_rank, p.requests
    ));
    b.note("pressured cells derive HBM capacity from the governor's formulas");
    b.note("(weights + activation reserve + KV pool at a fraction of demand);");
    b.note("headroom_util = mean grantable fraction of the replica budget;");
    b.note("EPLB slots cost n_layers x W, so its headroom collapses first");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test-scale params: same machinery, smaller shapes so debug-mode
    /// runs stay fast.
    fn small() -> MemoryParams {
        MemoryParams {
            scenarios: vec![
                MemoryScenario {
                    name: "short-ctx".into(),
                    prompt: 64,
                    new_tokens: 24,
                    pool_frac: 0.0,
                },
                MemoryScenario {
                    name: "long-ctx".into(),
                    prompt: 512,
                    new_tokens: 48,
                    pool_frac: 0.6,
                },
            ],
            balancers: vec![BalancerKind::StaticEp, BalancerKind::Probe],
            requests: 16,
            batch_per_rank: 4,
            chunk_per_rank: 16,
            max_steps: 4_000,
            seed: 5,
        }
    }

    #[test]
    fn memory_bench_emits_all_cells() {
        let p = small();
        let b = run(&p);
        assert_eq!(b.rows.len(), 4, "2 scenarios x 2 balancers");
        for row in &b.rows {
            let submitted: usize = row[2].parse().unwrap();
            let completed: usize = row[3].parse().unwrap();
            assert!(submitted > 0 && completed > 0, "{row:?}");
            assert!(completed <= submitted, "{row:?}");
            let util: f64 = row[9].parse().unwrap();
            assert!((0.0..=1.0).contains(&util), "{row:?}");
        }
        // identical stream per scenario across balancers
        let get = |scenario: &str, balancer: &str, col: usize| -> String {
            b.rows
                .iter()
                .find(|r| r[0] == scenario && r[1] == balancer)
                .unwrap()[col]
                .clone()
        };
        assert_eq!(get("long-ctx", "static", 2), get("long-ctx", "probe", 2));
    }

    #[test]
    fn long_ctx_cell_is_memory_pressured() {
        let p = small();
        let long = p.scenarios[1].clone();
        let reqs = scenario_stream(&long, &p);
        let cell = run_cell(&long, &p, BalancerKind::StaticEp, &reqs);
        assert_eq!(cell.completed, cell.submitted, "pressured cell must drain");
        assert!(
            cell.preemptions > 0,
            "long-ctx at a fractional KV pool must preempt"
        );
        assert!(
            cell.headroom_util < 0.999,
            "KV pressure never dented the replica headroom: {}",
            cell.headroom_util
        );
        // the unpressured control keeps its full headroom and never
        // preempts
        let short = p.scenarios[0].clone();
        let reqs = scenario_stream(&short, &p);
        let control = run_cell(&short, &p, BalancerKind::StaticEp, &reqs);
        assert_eq!(control.preemptions, 0);
        assert!(control.headroom_util > 0.999, "{}", control.headroom_util);
    }
}
