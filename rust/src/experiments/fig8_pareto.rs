//! Fig. 8: decoding throughput–latency Pareto frontier.
//!
//! GPT-OSS, ep=8, per-rank batch swept 512→1536 on *Chinese*, *Code* and
//! *Repeat*; throughput averaged over the first decode steps. PROBE
//! dominates the frontier (paper: up to 1.26× over one-shot EPLB at equal
//! batch), most visibly on the high-skew Repeat dataset.

use crate::config::BalancerKind;
use crate::coordinator::Coordinator;
use crate::util::bench::BenchSet;
use crate::workload::{Dataset, RequestGenerator, WorkloadSpec};

use super::{layer_scale, make_balancer, sim_config, SIM_LAYERS};

/// Fig. 8 sweep parameters.
pub struct Fig8Params {
    /// Per-rank decode batch sizes swept.
    pub batches_per_rank: Vec<usize>,
    /// Datasets swept.
    pub datasets: Vec<Dataset>,
    /// Decode steps per run.
    pub steps: usize,
    /// Simulation seed.
    pub seed: u64,
}

impl Default for Fig8Params {
    fn default() -> Self {
        Fig8Params {
            batches_per_rank: vec![512, 768, 1024, 1280, 1536],
            datasets: vec![Dataset::Chinese, Dataset::Code, Dataset::Repeat],
            steps: 60,
            seed: 23,
        }
    }
}

/// One decode run → (throughput tokens/s, mean TPOT seconds).
pub fn decode_run(
    kind: BalancerKind,
    dataset: Dataset,
    batch_per_rank: usize,
    steps: usize,
    seed: u64,
) -> (f64, f64) {
    let mut cfg = sim_config("gpt-oss-120b");
    let scale = layer_scale(&cfg);
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = batch_per_rank;
    cfg.dataset = dataset;
    let bal = make_balancer(kind, &cfg, seed);
    let mut c = Coordinator::new(cfg.clone(), bal, seed);
    let mut spec = WorkloadSpec::new(dataset, 4);
    spec.mean_prompt_len = 16; // decode-dominated runs
    spec.mean_new_tokens = 4 * steps;
    let mut g = RequestGenerator::new(spec, seed ^ 0x8);
    for r in g.take(cfg.global_batch() + 64) {
        c.submit(r);
    }
    let mut sim_time = 0.0;
    let mut tokens = 0usize;
    for _ in 0..steps {
        match c.decode_step() {
            Some(o) => {
                sim_time += o.latency * scale;
                tokens += c.active_count();
            }
            None => break,
        }
    }
    if sim_time <= 0.0 {
        return (0.0, 0.0);
    }
    let thr = tokens as f64 / sim_time;
    let tpot = sim_time / steps as f64;
    (thr, tpot)
}

/// Regenerate the Fig. 8 Pareto-frontier table.
pub fn run(p: &Fig8Params) -> BenchSet {
    let mut b = BenchSet::new(
        "fig8_decode_pareto",
        &[
            "dataset", "batch/rank", "system", "throughput_tok_s", "tpot_ms",
            "vs_eplb", "vs_static",
        ],
    );
    b.set_meta(super::bench_meta(&sim_config("gpt-oss-120b"), "fig8_pareto"));
    for &dataset in &p.datasets {
        for &bpr in &p.batches_per_rank {
            let (thr_s, tpot_s) =
                decode_run(BalancerKind::StaticEp, dataset, bpr, p.steps, p.seed);
            let (thr_e, tpot_e) = decode_run(BalancerKind::Eplb, dataset, bpr, p.steps, p.seed);
            let (thr_p, tpot_p) = decode_run(BalancerKind::Probe, dataset, bpr, p.steps, p.seed);
            for (name, thr, tpot) in [
                ("sglang", thr_s, tpot_s),
                ("eplb", thr_e, tpot_e),
                ("probe", thr_p, tpot_p),
            ] {
                b.row(&[
                    dataset.name().into(),
                    bpr.to_string(),
                    name.into(),
                    format!("{:.0}", thr),
                    format!("{:.2}", tpot * 1e3),
                    format!("{:.2}x", thr / thr_e.max(1e-9)),
                    format!("{:.2}x", thr / thr_s.max(1e-9)),
                ]);
            }
        }
    }
    b.note("paper: PROBE dominates the bottom-right frontier on all datasets;");
    b.note("up to 1.26x over EPLB at equal batch, largest on Repeat");
    b.note(&format!(
        "EPLB warm-up shortened to fit {}-step runs (full warm-up shown in fig9)",
        p.steps
    ));
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_dominates_on_repeat() {
        let (thr_s, _) = decode_run(BalancerKind::StaticEp, Dataset::Repeat, 512, 25, 1);
        let (thr_p, _) = decode_run(BalancerKind::Probe, Dataset::Repeat, 512, 25, 1);
        assert!(
            thr_p > thr_s * 1.03,
            "probe {thr_p} vs static {thr_s} on repeat"
        );
    }

    #[test]
    fn throughput_grows_with_batch() {
        let (thr_small, _) = decode_run(BalancerKind::Probe, Dataset::Code, 512, 20, 2);
        let (thr_big, _) = decode_run(BalancerKind::Probe, Dataset::Code, 1536, 20, 2);
        assert!(thr_big > thr_small, "{thr_small} -> {thr_big}");
    }

    #[test]
    fn tpot_grows_with_batch() {
        let (_, tpot_small) = decode_run(BalancerKind::Probe, Dataset::Code, 512, 20, 2);
        let (_, tpot_big) = decode_run(BalancerKind::Probe, Dataset::Code, 1536, 20, 2);
        assert!(tpot_big > tpot_small);
    }
}
