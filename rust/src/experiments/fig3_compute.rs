//! Fig. 3: MoE compute latency under EP (max/avg/min), DP, and
//! EP + extra experts.
//!
//! Shows the dilemma: EP maximizes arithmetic intensity but straggles;
//! DP is balanced but fragmented (memory-bound cold experts, padding);
//! modest EP redundancy neutralizes the straggler at minimal memory cost.

use crate::config::ProbeConfig;
use crate::model::MoeModel;
use crate::perfmodel::{expert_compute_time, Assignment};
use crate::placement::Placement;
use crate::planner;
use crate::routing::RoutingModel;
use crate::topology::HardwareProfile;
use crate::util::bench::BenchSet;
use crate::util::stats;

/// Fig. 3 sweep parameters.
pub struct Fig3Params {
    /// Expert-parallel group size.
    pub ep: usize,
    /// Token counts swept.
    pub token_counts: Vec<usize>,
    /// Redundant experts for the EP+extra series.
    pub extra_experts: usize,
    /// Routing-model seed.
    pub seed: u64,
}

impl Default for Fig3Params {
    fn default() -> Self {
        Fig3Params {
            ep: 8,
            token_counts: vec![2048, 4096, 8192, 16384],
            extra_experts: 4,
            seed: 7,
        }
    }
}

/// Per-rank compute times for an assignment.
fn rank_times(a: &Assignment, model: &MoeModel, hw: &HardwareProfile) -> Vec<f64> {
    let loads = a.rank_expert_loads();
    crate::perfmodel::rank_compute_times(&loads, model, hw)
}

/// Regenerate the Fig. 3 MoE-compute table.
pub fn run(p: &Fig3Params) -> BenchSet {
    let model = MoeModel::gpt_oss_120b();
    let hw = HardwareProfile::hopper_141();
    let mut b = BenchSet::new(
        "fig3_moe_compute",
        &[
            "tokens", "EP_max_ms", "EP_avg_ms", "EP_min_ms", "DP_ms",
            "EP+extra_max_ms", "EP_skew", "EP+extra_skew",
        ],
    );
    {
        let mut meta_cfg = crate::config::Config::default();
        meta_cfg.model = model.clone();
        meta_cfg.cluster.ep = p.ep;
        b.set_meta(super::bench_meta(&meta_cfg, "fig3_compute"));
    }
    let mut rm = RoutingModel::calibrated(1, model.n_experts, model.top_k, 4, p.seed);
    for &tokens in &p.token_counts {
        let routing = rm.route_step(&vec![0u16; tokens]).layers.remove(0);
        let counts: Vec<Vec<f64>> = routing
            .expert_counts_by_source(p.ep)
            .into_iter()
            .map(|v| v.into_iter().map(f64::from).collect())
            .collect();

        // EP: static shard
        let shard = Placement::sharded(p.ep, model.n_experts, 0);
        let ep_a = Assignment::locality_first_from_counts(&counts, &shard);
        let ep_t = rank_times(&ep_a, &model, &hw);

        // DP: every rank replicates all experts, processes its local
        // tokens only → n_e/ep tokens per expert per rank (fragmented).
        let global = routing.expert_counts();
        let dp_rank: f64 = global
            .iter()
            .map(|&n| expert_compute_time(n as f64 / p.ep as f64, &model, &hw))
            .sum();

        // EP + extra experts: planner with a per-rank budget of
        // `extra_experts` and an unconstrained window (static redundancy).
        let mut cfg = ProbeConfig::default();
        cfg.max_redundant = p.extra_experts;
        cfg.k_max = 64;
        let base = Placement::sharded(p.ep, model.n_experts, p.extra_experts);
        let out = planner::plan(&counts, &base, &model, &hw, &vec![1.0; p.ep], &cfg);
        let extra_t = rank_times(&out.assignment, &model, &hw);

        let ms = |x: f64| format!("{:.2}", x * 1e3);
        b.row(&[
            tokens.to_string(),
            ms(stats::max(&ep_t)),
            ms(stats::mean(&ep_t)),
            ms(stats::min(&ep_t)),
            ms(dp_rank),
            ms(stats::max(&extra_t)),
            format!("{:.2}", stats::imbalance_ratio(&ep_t)),
            format!("{:.2}", stats::imbalance_ratio(&extra_t)),
        ]);
    }
    b.note("paper: DP bottlenecked by fragmentation; EP by the straggler;");
    b.note("modest redundancy ≈ EP_avg with minimal memory overhead");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_of_fig3_hold() {
        let p = Fig3Params {
            token_counts: vec![8192, 16384],
            ..Default::default()
        };
        let b = run(&p);
        let mut best_closed = 0.0f64;
        for row in &b.rows {
            let ep_max: f64 = row[1].parse().unwrap();
            let ep_avg: f64 = row[2].parse().unwrap();
            let ep_min: f64 = row[3].parse().unwrap();
            let dp: f64 = row[4].parse().unwrap();
            let extra_max: f64 = row[5].parse().unwrap();
            // straggler gap exists
            assert!(ep_max > ep_avg && ep_avg > ep_min);
            // DP pays fragmentation: worse than balanced EP average
            assert!(dp > ep_avg, "DP {dp} <= EP avg {ep_avg}");
            // redundancy never hurts
            assert!(extra_max <= ep_max, "extra {extra_max} > EP max {ep_max}");
            let closed = (ep_max - extra_max) / (ep_max - ep_avg).max(1e-12);
            best_closed = best_closed.max(closed);
        }
        // at least one (high-skew) operating point closes half the gap
        assert!(best_closed > 0.5, "best gap closure only {best_closed:.2}");
    }
}
