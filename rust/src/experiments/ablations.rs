//! Design-choice ablations (DESIGN.md list): prefetch budget, predictor
//! quality, lookahead depth, delta vs clear-every-layer planning,
//! split-phase transmission, water-filling, hiding-window enforcement.
//! Each row reports decode throughput, mean IR, exposed transfer, and
//! the expert-fetch volume on the high-skew Repeat workload where the
//! mechanisms matter most (the routing model's default drift makes it
//! the ISSUE 2 "drift workload").

use crate::config::{PredictorKind, ProbeConfig};
use crate::coordinator::Coordinator;
use crate::util::bench::BenchSet;
use crate::workload::{Dataset, RequestGenerator, WorkloadSpec};

use super::{sim_config, SIM_LAYERS};

/// (name, throughput tok/s, mean IR, exposed seconds, fetch slots)
type VariantRow = (String, f64, f64, f64, usize);

fn run_variant(
    name: &str,
    cfg_probe: ProbeConfig,
    split_phase: bool,
    steps: usize,
    seed: u64,
) -> VariantRow {
    run_variant_on(name, cfg_probe, split_phase, steps, seed, "hopper-141")
}

/// The split-phase / hiding-window mechanisms only bind when transfers
/// are slow relative to the compute window; those variants run on the
/// compute-heavy (bandwidth-poor) profile (paper §2.3).
fn run_variant_on(
    name: &str,
    cfg_probe: ProbeConfig,
    split_phase: bool,
    steps: usize,
    seed: u64,
    profile: &str,
) -> VariantRow {
    let mut cfg = sim_config("gpt-oss-120b");
    cfg.cluster.profile = crate::topology::HardwareProfile::by_name(profile).unwrap();
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = 768;
    cfg.probe = cfg_probe.clone();
    let bal = Box::new(crate::balancers::Probe::new(&cfg, cfg_probe, seed));
    let mut c = Coordinator::new(cfg.clone(), bal, seed);
    c.executor.sim.split_phase = split_phase;
    let mut spec = WorkloadSpec::new(Dataset::Repeat, 4);
    spec.mean_prompt_len = 8;
    spec.mean_new_tokens = steps * 2;
    let mut g = RequestGenerator::new(spec, seed ^ 3);
    for r in g.take(cfg.global_batch() + 16) {
        c.submit(r);
    }
    let outs = c.run_decode_steps(steps);
    let lat: f64 = outs.iter().map(|o| o.latency).sum();
    let toks: usize = outs.iter().map(|_| c.decode_capacity()).sum();
    let ir = crate::util::stats::mean(&outs.iter().map(|o| o.mean_ir()).collect::<Vec<_>>());
    let exposed: f64 = outs.iter().map(|o| o.total_exposed()).sum();
    let fetches: usize = outs.iter().map(|o| o.prefetch_slots_total).sum();
    (
        name.to_string(),
        if lat > 0.0 { toks as f64 / lat } else { 0.0 },
        ir,
        exposed,
        fetches,
    )
}

/// Run every design-choice ablation for `steps` decode steps.
pub fn run(steps: usize) -> BenchSet {
    let mut b = BenchSet::new(
        "ablations",
        &[
            "variant",
            "throughput_tok_s",
            "mean_IR",
            "exposed_us",
            "fetch_slots",
        ],
    );
    b.set_meta(super::bench_meta(&sim_config("gpt-oss-120b"), "ablations"));
    let seed = 51;
    let mut variants: Vec<VariantRow> = Vec::new();

    // the default config is the shared point of four sweeps
    // (budget=3, predictor=distilled, lookahead=1, delta_plan=on):
    // simulate it once, emit it under each label
    let baseline = run_variant("baseline", ProbeConfig::default(), true, steps, seed);
    let alias =
        |name: &str, v: &VariantRow| -> VariantRow { (name.to_string(), v.1, v.2, v.3, v.4) };

    // prefetch budget sweep
    for budget in [0usize, 1, 2] {
        let mut p = ProbeConfig::default();
        p.max_redundant = budget;
        variants.push(run_variant(&format!("budget={budget}"), p, true, steps, seed));
    }
    variants.push(alias("budget=3", &baseline));
    // predictor quality sweep
    variants.push(alias("predictor=distilled", &baseline));
    for (name, acc) in [("oracle", 1.0), ("untrained", 0.75), ("poor", 0.4)] {
        let mut p = ProbeConfig::default();
        p.predictor_accuracy = acc;
        variants.push(run_variant(&format!("predictor={name}"), p, true, steps, seed));
    }
    // causal transition predictor (no harness oracle at all)
    {
        let mut p = ProbeConfig::default();
        p.predictor_kind = PredictorKind::Transition;
        variants.push(run_variant("predictor=transition", p, true, steps, seed));
    }
    // lookahead depth sweep (ISSUE 2 acceptance: {1, 2, 4} via config)
    variants.push(alias("lookahead=1", &baseline));
    for depth in [2usize, 4] {
        let mut p = ProbeConfig::default();
        p.lookahead_depth = depth;
        variants.push(run_variant(&format!("lookahead={depth}"), p, true, steps, seed));
    }
    // delta planning vs clear-every-layer on the drift workload
    variants.push(alias("delta_plan=on", &baseline));
    {
        let mut p = ProbeConfig::default();
        p.delta_plan = false;
        variants.push(run_variant("delta_plan=off", p, true, steps, seed));
    }
    // split-phase on/off under a tight window (compute-heavy profile)
    variants.push(run_variant_on(
        "tight/split_phase=on",
        ProbeConfig::default(),
        true,
        steps,
        seed,
        "compute-heavy",
    ));
    variants.push(run_variant_on(
        "tight/split_phase=off",
        ProbeConfig::default(),
        false,
        steps,
        seed,
        "compute-heavy",
    ));
    // §6.4 extension: predictive pre-dispatch
    {
        let mut p = ProbeConfig::default();
        p.pre_dispatch = true;
        variants.push(run_variant("pre_dispatch=on (§6.4)", p, true, steps, seed));
    }
    // naive half-split instead of water-filling
    {
        let mut p = ProbeConfig::default();
        p.water_filling = false;
        variants.push(run_variant("water_filling=off", p, true, steps, seed));
    }
    // hiding-window enforcement on/off under a tight window
    {
        let mut p = ProbeConfig::default();
        p.enforce_window = false;
        variants.push(run_variant_on(
            "tight/enforce_window=off",
            p,
            true,
            steps,
            seed,
            "compute-heavy",
        ));
        variants.push(run_variant_on(
            "tight/enforce_window=on",
            ProbeConfig::default(),
            true,
            steps,
            seed,
            "compute-heavy",
        ));
    }

    for (name, thr, ir, exposed, fetches) in variants {
        b.row(&[
            name,
            format!("{:.0}", thr),
            format!("{:.2}", ir),
            format!("{:.1}", exposed * 1e6),
            format!("{fetches}"),
        ]);
    }
    b.note("Repeat dataset, GPT-OSS, ep=8, b=768/rank (highest-skew regime)");
    b.note("fetch_slots: experts transferred across all layers/steps;");
    b.note("delta planning reuses resident replicas, clear mode refetches");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_zero_is_static_like_and_three_helps() {
        let b = run(12);
        let find = |name: &str| -> (f64, f64) {
            let row = b.rows.iter().find(|r| r[0] == name).unwrap();
            (row[1].parse().unwrap(), row[2].parse().unwrap())
        };
        let (thr0, ir0) = find("budget=0");
        let (thr3, ir3) = find("budget=3");
        assert!(thr3 > thr0, "budget 3 ({thr3}) <= budget 0 ({thr0})");
        assert!(ir3 < ir0, "IR did not improve with budget");
    }

    #[test]
    fn oracle_at_least_as_good_as_poor_predictor() {
        let b = run(12);
        let thr = |name: &str| -> f64 {
            b.rows.iter().find(|r| r[0] == name).unwrap()[1]
                .parse()
                .unwrap()
        };
        assert!(thr("predictor=oracle") >= thr("predictor=poor") * 0.98);
    }

    #[test]
    fn delta_planning_cuts_fetches_on_drift_workload() {
        let b = run(12);
        let fetches = |name: &str| -> usize {
            b.rows.iter().find(|r| r[0] == name).unwrap()[4]
                .parse()
                .unwrap()
        };
        let on = fetches("delta_plan=on");
        let off = fetches("delta_plan=off");
        assert!(off > 0, "clear mode never fetched");
        assert!(on < off, "delta {on} >= clear {off}");
    }

    #[test]
    fn lookahead_sweep_rows_present() {
        let b = run(8);
        for depth in [1, 2, 4] {
            assert!(
                b.rows.iter().any(|r| r[0] == format!("lookahead={depth}")),
                "missing lookahead={depth} row"
            );
        }
    }
}
