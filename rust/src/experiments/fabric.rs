//! `probe bench fabric` — multi-node interconnect sweep (beyond-paper).
//!
//! Sweeps cluster shape (ranks × nodes) and inter-node bandwidth ratio
//! {1/4, 1/8, 1/16} of NVSwitch, comparing topology-aware planning
//! (`probe.topology_aware = true`: intra-node fetch sources, per-link
//! window feasibility, rail congestion in the objective) against the
//! topology-blind ablation on the SAME fabric. Emits
//! `bench_results/BENCH_fabric.json` with exposed-transfer and
//! decode-throughput rows per configuration, plus a flat-fabric
//! equivalence probe (max deviation of the single-node fabric from the
//! pre-fabric scalar model — must be ~0).

use crate::balancers::{decide_step, Probe};
use crate::config::{BalancerKind, Config, ProbeConfig};
use crate::fabric::Fabric;
use crate::perfmodel::{self, TrafficMatrix};
use crate::routing::RoutingModel;
use crate::simulator::ClusterSim;
use crate::topology::{Cluster, HardwareProfile};
use crate::util::bench::BenchSet;
use crate::util::stats::mean;
use crate::util::Rng;

use super::SIM_LAYERS;

/// Fabric sweep parameters.
pub struct FabricParams {
    /// Decode steps per configuration.
    pub steps: usize,
    /// Decode tokens per rank.
    pub batch_per_rank: usize,
    /// (ep, nodes) cluster shapes to sweep.
    pub shapes: Vec<(usize, usize)>,
    /// Per-rail inter-node bandwidth as a fraction of NVSwitch.
    pub ratios: Vec<f64>,
    /// Inter-node rails per node.
    pub rails: usize,
    /// Sweep seed.
    pub seed: u64,
}

impl Default for FabricParams {
    fn default() -> Self {
        FabricParams {
            steps: 16,
            batch_per_rank: 768,
            shapes: vec![(16, 2), (32, 4)],
            ratios: vec![0.25, 0.125, 0.0625],
            rails: 2,
            seed: 51,
        }
    }
}

/// One probe run on one fabric: (mean step latency s, total exposed s,
/// decode throughput tok/s).
pub fn run_probe_on_fabric(
    ep: usize,
    nodes: usize,
    ratio: f64,
    rails: usize,
    aware: bool,
    steps: usize,
    batch_per_rank: usize,
    seed: u64,
) -> (f64, f64, f64) {
    let mut cfg = Config::default();
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = batch_per_rank;
    cfg.cluster = Cluster::multi_node_ratio(
        ep,
        nodes,
        HardwareProfile::hopper_141(),
        ratio,
        rails,
    );
    let mut pc = ProbeConfig::default();
    pc.topology_aware = aware;
    let mut bal = Probe::new(&cfg, pc, seed);
    let mut sim = ClusterSim::new(cfg.model.clone(), cfg.cluster.clone());
    let mut rm = RoutingModel::calibrated(
        SIM_LAYERS,
        cfg.model.n_experts,
        cfg.model.top_k,
        4,
        seed,
    );
    let tokens = cfg.global_batch();
    let mut lats = Vec::with_capacity(steps);
    let mut exposed = 0.0;
    for step in 0..steps {
        let routing = rm.route_step(&vec![0u16; tokens]);
        let ds = decide_step(&mut bal, step, &routing);
        let out = sim.run_step(&routing, &ds);
        lats.push(out.latency);
        exposed += out.total_exposed();
        rm.step_drift();
    }
    let total: f64 = lats.iter().sum();
    let tput = if total > 0.0 {
        tokens as f64 * steps as f64 / total
    } else {
        0.0
    };
    (mean(&lats), exposed, tput)
}

/// One non-PROBE balancer run on one fabric (same loop as
/// [`run_probe_on_fabric`], balancer picked by kind): (mean step
/// latency s, total exposed s, decode throughput tok/s). Used for the
/// HarMoEny rows — reactive rescheduling has no topology awareness to
/// toggle, so it gets one arm per fabric point.
pub fn run_kind_on_fabric(
    kind: BalancerKind,
    ep: usize,
    nodes: usize,
    ratio: f64,
    rails: usize,
    steps: usize,
    batch_per_rank: usize,
    seed: u64,
) -> (f64, f64, f64) {
    let mut cfg = Config::default();
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = batch_per_rank;
    cfg.cluster = Cluster::multi_node_ratio(
        ep,
        nodes,
        HardwareProfile::hopper_141(),
        ratio,
        rails,
    );
    let mut bal = super::make_balancer(kind, &cfg, seed);
    let mut sim = ClusterSim::new(cfg.model.clone(), cfg.cluster.clone());
    let mut rm = RoutingModel::calibrated(
        SIM_LAYERS,
        cfg.model.n_experts,
        cfg.model.top_k,
        4,
        seed,
    );
    let tokens = cfg.global_batch();
    let mut lats = Vec::with_capacity(steps);
    let mut exposed = 0.0;
    for step in 0..steps {
        let routing = rm.route_step(&vec![0u16; tokens]);
        let ds = decide_step(bal.as_mut(), step, &routing);
        let out = sim.run_step(&routing, &ds);
        lats.push(out.latency);
        exposed += out.total_exposed();
        rm.step_drift();
    }
    let total: f64 = lats.iter().sum();
    let tput = if total > 0.0 {
        tokens as f64 * steps as f64 / total
    } else {
        0.0
    };
    (mean(&lats), exposed, tput)
}

/// Max |flat-fabric − scalar-model| All-to-All deviation over random
/// traffic matrices (the equivalence the default config relies on).
pub fn flat_equivalence_err(ep: usize, cases: usize, seed: u64) -> f64 {
    let hw = HardwareProfile::hopper_141();
    let fabric = Fabric::flat(ep, &hw);
    let mut rng = Rng::new(seed);
    let mut worst = 0.0f64;
    for _ in 0..cases {
        let mut m = TrafficMatrix::new(ep);
        for s in 0..ep {
            for d in 0..ep {
                m.add(s, d, rng.range_f64(0.0, 5e6));
            }
        }
        let scalar = perfmodel::alltoall_time(&m.volumes(), &hw);
        worst = worst.max((fabric.alltoall_time(&m) - scalar).abs());
    }
    worst
}

/// Run the fabric sweep → `bench_results/BENCH_fabric.json`.
pub fn run(p: &FabricParams) -> BenchSet {
    let mut b = BenchSet::new("BENCH_fabric", &["metric", "value", "unit"]);
    b.set_meta(super::bench_meta(
        &crate::config::Config::default(),
        "fabric",
    ));

    b.row(&[
        "flat_equiv_max_abs_err".into(),
        format!("{:.3e}", flat_equivalence_err(8, 50, p.seed)),
        "s".into(),
    ]);

    for &(ep, nodes) in &p.shapes {
        for &ratio in &p.ratios {
            let denom = (1.0 / ratio).round() as usize;
            let mut results = Vec::new();
            for aware in [true, false] {
                let (lat, exposed, tput) = run_probe_on_fabric(
                    ep,
                    nodes,
                    ratio,
                    p.rails,
                    aware,
                    p.steps,
                    p.batch_per_rank,
                    p.seed,
                );
                let tag = if aware { "aware" } else { "blind" };
                b.row(&[
                    format!("ep{ep}x{nodes}_r{denom}_{tag}_exposed"),
                    format!("{:.1}", exposed * 1e6),
                    "us".into(),
                ]);
                b.row(&[
                    format!("ep{ep}x{nodes}_r{denom}_{tag}_step_latency"),
                    format!("{:.1}", lat * 1e6),
                    "us".into(),
                ]);
                b.row(&[
                    format!("ep{ep}x{nodes}_r{denom}_{tag}_throughput"),
                    format!("{:.0}", tput),
                    "tok/s".into(),
                ]);
                results.push((exposed, tput));
            }
            // the token-rescheduling baseline on the identical fabric:
            // reactive fetches pay the slow rails with no prefetch window
            let (lat_h, exp_h, tput_h) = run_kind_on_fabric(
                BalancerKind::HarMoEny,
                ep,
                nodes,
                ratio,
                p.rails,
                p.steps,
                p.batch_per_rank,
                p.seed,
            );
            b.row(&[
                format!("ep{ep}x{nodes}_r{denom}_harmoeny_exposed"),
                format!("{:.1}", exp_h * 1e6),
                "us".into(),
            ]);
            b.row(&[
                format!("ep{ep}x{nodes}_r{denom}_harmoeny_step_latency"),
                format!("{:.1}", lat_h * 1e6),
                "us".into(),
            ]);
            b.row(&[
                format!("ep{ep}x{nodes}_r{denom}_harmoeny_throughput"),
                format!("{:.0}", tput_h),
                "tok/s".into(),
            ]);
            let (exp_aware, tput_aware) = results[0];
            let (exp_blind, tput_blind) = results[1];
            b.row(&[
                format!("ep{ep}x{nodes}_r{denom}_exposed_saved"),
                format!("{:.1}", (exp_blind - exp_aware) * 1e6),
                "us".into(),
            ]);
            b.row(&[
                format!("ep{ep}x{nodes}_r{denom}_throughput_gain"),
                format!("{:.3}", if tput_blind > 0.0 { tput_aware / tput_blind } else { 1.0 }),
                "x".into(),
            ]);
        }
    }
    b.note(format!(
        "GPT-OSS decode, b={}/rank, {} steps, rails={} per node;",
        p.batch_per_rank, p.steps, p.rails
    ));
    b.note("aware = intra-node sources + per-link window feasibility +");
    b.note("rail congestion in the plan objective; blind = pre-fabric");
    b.note("scalar checks on the same multi-node fabric; harmoeny =");
    b.note("reactive token rescheduling (no prefetch window) on the");
    b.note("identical fabric");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_fabric_is_equivalent_to_scalar_model() {
        let err = flat_equivalence_err(8, 30, 7);
        assert!(err < 1e-9, "flat fabric deviates from scalar model: {err}");
    }

    #[test]
    fn topology_aware_beats_blind_on_slow_rails() {
        // acceptance: ≥16 ranks over ≥2 nodes, inter-node bw 1/8 of
        // NVSwitch → aware planning must strictly reduce exposed
        // transfer vs blind planning on the identical fabric
        let (_, exposed_aware, tput_aware) =
            run_probe_on_fabric(16, 2, 0.125, 2, true, 6, 256, 13);
        let (_, exposed_blind, tput_blind) =
            run_probe_on_fabric(16, 2, 0.125, 2, false, 6, 256, 13);
        assert!(
            exposed_blind > 0.0,
            "blind planner never exposed transfer (fabric not binding)"
        );
        assert!(
            exposed_aware < exposed_blind,
            "aware exposed {exposed_aware} not below blind {exposed_blind}"
        );
        assert!(tput_aware > 0.0 && tput_blind > 0.0);
    }

    #[test]
    fn fabric_bench_emits_all_metric_families() {
        let p = FabricParams {
            steps: 3,
            batch_per_rank: 128,
            shapes: vec![(16, 2)],
            ratios: vec![0.125],
            rails: 2,
            seed: 3,
        };
        let b = run(&p);
        for needle in [
            "flat_equiv_max_abs_err",
            "ep16x2_r8_aware_exposed",
            "ep16x2_r8_blind_exposed",
            "ep16x2_r8_harmoeny_exposed",
            "ep16x2_r8_harmoeny_throughput",
            "ep16x2_r8_exposed_saved",
            "ep16x2_r8_throughput_gain",
        ] {
            assert!(
                b.rows.iter().any(|r| r[0] == needle),
                "missing metric {needle}"
            );
        }
    }
}
