//! `probe bench volatility` — cross-balancer workload-volatility sweep.
//!
//! Runs every scenario preset (`steady`/`burst`/`storm`/`drift`/
//! `multi_tenant`, see [`crate::workload::scenario`]) against all four
//! balancing systems {static, EPLB, HarMoEny, PROBE} on the serving
//! engine and
//! reports TTFT/TPOT percentiles, decode throughput, exposed transfer,
//! and the per-window **hotspot-migration rate**
//! ([`crate::metrics::HotspotTracker`]) → `bench_results/BENCH_volatility.json`.
//!
//! Scenario rates are *self-calibrating*: a short closed-loop run under
//! the static balancer measures the mean decode-step latency, and the
//! preset's absolute arrival rate is derived so the offered load is a
//! fixed fraction (`load`) of the engine's decode service capacity.
//! The same calibration fixes the horizon (`steps` step-units), so the
//! sweep is portable across batch sizes and hardware profiles — and
//! every balancer sees the *identical* request stream per preset.

use crate::config::{BalancerKind, Config};
use crate::coordinator::Coordinator;
use crate::metrics::HotspotTracker;
use crate::util::bench::BenchSet;
use crate::util::stats::Summary;
use crate::workload::{
    Dataset, Request, RequestGenerator, Scenario, ScenarioGenerator, WorkloadSpec,
};

use super::{make_balancer, SIM_LAYERS};

/// Sweep parameters.
pub struct VolatilityParams {
    /// Scenario presets to run (defaults to all of [`Scenario::PRESETS`]).
    pub presets: Vec<String>,
    /// Balancers to compare.
    pub balancers: Vec<BalancerKind>,
    /// Offered load as a fraction of calibrated decode capacity.
    pub load: f64,
    /// Scenario horizon in decode-step units.
    pub steps: usize,
    /// Decode tokens per rank (kept small so queueing is visible).
    pub batch_per_rank: usize,
    /// Mean decode budget per request (tokens).
    pub mean_new_tokens: usize,
    /// Hotspot-tracker window in steps.
    pub window: usize,
    /// Safety cap on decode steps per cell.
    pub max_steps: usize,
    /// Root seed (streams and balancers derive from it).
    pub seed: u64,
}

impl Default for VolatilityParams {
    fn default() -> Self {
        VolatilityParams {
            presets: Scenario::PRESETS.iter().map(|s| s.to_string()).collect(),
            balancers: BalancerKind::ALL.to_vec(),
            load: 0.7,
            steps: 200,
            batch_per_rank: 2,
            mean_new_tokens: 32,
            window: 10,
            max_steps: 20_000,
            seed: 37,
        }
    }
}

fn volatility_cfg(p: &VolatilityParams) -> Config {
    let mut cfg = Config::default();
    cfg.model.n_layers = SIM_LAYERS;
    cfg.batch_per_rank = p.batch_per_rank;
    cfg.prefill_chunk_per_rank = 1024;
    cfg
}

/// Mean decode-step latency (simulated seconds) of a short closed-loop
/// run under the static balancer on an arbitrary serving config — the
/// time base scenarios calibrate against.
pub fn calibrate_step_latency_for(cfg: &Config, seed: u64) -> f64 {
    let bal = make_balancer(BalancerKind::StaticEp, cfg, seed);
    let mut c = Coordinator::new(cfg.clone(), bal, seed);
    let mut spec = WorkloadSpec::new(Dataset::Mixed, 4);
    spec.mean_prompt_len = 16;
    spec.mean_new_tokens = 64;
    let mut g = RequestGenerator::new(spec, seed ^ 0xCA1B);
    c.submit_all(g.take(cfg.global_batch() + 8));
    let outs = c.run_decode_steps(12);
    let lat: Vec<f64> = outs.iter().map(|o| o.latency).collect();
    let t = crate::util::stats::mean(&lat);
    assert!(t > 0.0, "calibration produced no steps");
    t
}

/// [`calibrate_step_latency_for`] on the sweep's own config.
pub fn calibrate_step_latency(p: &VolatilityParams) -> f64 {
    calibrate_step_latency_for(&volatility_cfg(p), p.seed)
}

/// Build a preset scenario for an arbitrary serving config, sized to
/// the calibrated step latency: the horizon spans `steps` step-units
/// and the total base arrival rate offers `load ×` the engine's decode
/// service capacity (`capacity / mean_new_tokens` requests per step).
pub fn build_scenario_for(
    cfg: &Config,
    preset: &str,
    load: f64,
    steps: usize,
    mean_new_tokens: usize,
    t_step: f64,
) -> Option<Scenario> {
    let capacity = cfg.global_batch() as f64;
    let duration = steps as f64 * t_step;
    // one request occupies a decode slot for ~mean_new_tokens steps
    let service_rate = capacity / (mean_new_tokens as f64 * t_step);
    let base_rate = load * service_rate;
    let mut s = Scenario::preset(preset, base_rate, duration, 4)?;
    for t in &mut s.tenants {
        t.spec.mean_prompt_len = 16;
        t.spec.mean_new_tokens = mean_new_tokens;
    }
    Some(s)
}

/// [`build_scenario_for`] on the sweep's own config. Panics on unknown
/// presets (sweep inputs are validated upstream).
pub fn build_scenario(preset: &str, p: &VolatilityParams, t_step: f64) -> Scenario {
    build_scenario_for(
        &volatility_cfg(p),
        preset,
        p.load,
        p.steps,
        p.mean_new_tokens,
        t_step,
    )
    .unwrap_or_else(|| panic!("unknown scenario preset {preset:?}"))
}

/// Calibrate and generate a scenario request stream for an arbitrary
/// serving config (the `probe simulate --scenario` / `[scenario]` TOML
/// path). Returns `Err` on unknown presets.
pub fn scenario_stream_for(
    cfg: &Config,
    preset: &str,
    load: f64,
    steps: usize,
    seed: u64,
) -> Result<Vec<Request>, String> {
    let t_step = calibrate_step_latency_for(cfg, seed);
    let scenario = build_scenario_for(cfg, preset, load, steps, 32, t_step)
        .ok_or_else(|| format!("unknown scenario preset {preset:?}"))?;
    Ok(ScenarioGenerator::new(scenario, seed).generate())
}

/// Outcome of one (preset, balancer) cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// Requests submitted.
    pub submitted: usize,
    /// Requests that completed within the step cap.
    pub completed: usize,
    /// Decode steps executed.
    pub steps: usize,
    /// Aggregate decode throughput (tokens/s).
    pub throughput: f64,
    /// TTFT distribution (seconds).
    pub ttft: Summary,
    /// TPOT distribution (seconds).
    pub tpot: Summary,
    /// Total exposed (non-hidden) transfer seconds.
    pub exposed: f64,
    /// Per-window hotspot-migration rate in [0, 1].
    pub hotspot_migration: f64,
}

/// Serve one request stream under one balancer and collect the cell
/// metrics. Every balancer must be given the identical stream so the
/// comparison isolates the balancing system.
pub fn run_cell(p: &VolatilityParams, kind: BalancerKind, reqs: &[Request]) -> CellResult {
    let cfg = volatility_cfg(p);
    let bal = make_balancer(kind, &cfg, p.seed);
    let mut c = Coordinator::new(cfg, bal, p.seed);
    c.submit_all(reqs.iter().cloned());
    let mut hot = HotspotTracker::new(p.window);
    let mut exposed = 0.0;
    let mut steps = 0usize;
    while steps < p.max_steps {
        match c.decode_step() {
            Some(o) => {
                exposed += o.total_exposed();
                hot.push_loads(&o.rank_token_loads);
                steps += 1;
            }
            None => break,
        }
    }
    CellResult {
        submitted: reqs.len(),
        completed: c
            .metrics
            .requests
            .iter()
            .filter(|m| m.finished.is_some())
            .count(),
        steps,
        throughput: c.metrics.throughput(),
        ttft: c.metrics.ttft_summary(),
        tpot: c.metrics.tpot_summary(),
        exposed,
        hotspot_migration: hot.migration_rate(),
    }
}

/// Run the full sweep and emit `bench_results/BENCH_volatility.json`.
pub fn run(p: &VolatilityParams) -> BenchSet {
    let mut b = BenchSet::new(
        "BENCH_volatility",
        &[
            "scenario",
            "balancer",
            "requests",
            "completed",
            "tok_s",
            "ttft_p50_ms",
            "ttft_p99_ms",
            "tpot_p50_ms",
            "exposed_ms",
            "hotspot_migration",
        ],
    );
    b.set_meta(super::bench_meta(&volatility_cfg(p), &p.presets.join(",")));
    let t_step = calibrate_step_latency(p);
    for (idx, preset) in p.presets.iter().enumerate() {
        let scenario = build_scenario(preset, p, t_step);
        // distinct stream seed per preset slot (the preset name itself
        // is not hashed: same-length names must not collide)
        let stream_seed = p.seed ^ (idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let reqs = ScenarioGenerator::new(scenario, stream_seed).generate();
        for &kind in &p.balancers {
            let cell = run_cell(p, kind, &reqs);
            b.row(&[
                preset.clone(),
                kind.name().to_string(),
                cell.submitted.to_string(),
                cell.completed.to_string(),
                format!("{:.0}", cell.throughput),
                format!("{:.2}", cell.ttft.p50 * 1e3),
                format!("{:.2}", cell.ttft.p99 * 1e3),
                format!("{:.3}", cell.tpot.p50 * 1e3),
                format!("{:.3}", cell.exposed * 1e3),
                format!("{:.3}", cell.hotspot_migration),
            ]);
        }
    }
    b.note(&format!(
        "self-calibrated: t_step {:.1}us (static closed-loop), load {:.0}% of \
         decode capacity, horizon {} steps, {} sim layers, batch/rank {}",
        t_step * 1e6,
        p.load * 100.0,
        p.steps,
        SIM_LAYERS,
        p.batch_per_rank
    ));
    b.note("identical request stream per scenario across balancers;");
    b.note("hotspot_migration = per-window argmax-rank migration rate");
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> VolatilityParams {
        VolatilityParams {
            presets: vec!["steady".into(), "storm".into()],
            balancers: vec![BalancerKind::StaticEp, BalancerKind::Probe],
            load: 0.7,
            steps: 40,
            batch_per_rank: 1,
            mean_new_tokens: 16,
            window: 5,
            max_steps: 3_000,
            seed: 5,
        }
    }

    #[test]
    fn volatility_bench_emits_all_cells() {
        let p = small();
        let b = run(&p);
        assert_eq!(b.rows.len(), 4, "2 presets x 2 balancers");
        for row in &b.rows {
            let submitted: usize = row[2].parse().unwrap();
            let completed: usize = row[3].parse().unwrap();
            assert!(submitted > 0, "{row:?}: empty stream");
            assert!(completed > 0, "{row:?}: nothing completed");
            assert!(
                completed <= submitted,
                "{row:?}: completed more than submitted"
            );
            let migration: f64 = row[9].parse().unwrap();
            assert!((0.0..=1.0).contains(&migration), "{row:?}");
        }
        // scenario cells exist for both balancers with the same stream
        let stream_size = |scenario: &str, balancer: &str| -> usize {
            b.rows
                .iter()
                .find(|r| r[0] == scenario && r[1] == balancer)
                .unwrap()[2]
                .parse()
                .unwrap()
        };
        assert_eq!(
            stream_size("storm", "static"),
            stream_size("storm", "probe"),
            "balancers must see the identical stream"
        );
    }

    #[test]
    fn storm_cell_migrates_hotspots_and_calibration_sizes_stream() {
        let mut p = small();
        p.steps = 60;
        let t_step = calibrate_step_latency(&p);
        assert!(t_step > 0.0 && t_step.is_finite());
        let scenario = build_scenario("storm", &p, t_step);
        // horizon spans the requested step budget at the calibrated rate
        assert!((scenario.duration - 60.0 * t_step).abs() < 1e-12);
        let reqs = ScenarioGenerator::new(scenario, 11).generate();
        // offered load 0.7 of capacity: the stream is sized to roughly
        // load x capacity x steps / mean_new_tokens requests (Poisson)
        let expect = 0.7 * 8.0 * 60.0 / 16.0;
        assert!(
            (reqs.len() as f64) > expect * 0.4 && (reqs.len() as f64) < expect * 2.5,
            "stream size {} far from calibrated target {expect:.0}",
            reqs.len()
        );
        let cell = run_cell(&p, BalancerKind::StaticEp, &reqs);
        assert!(cell.completed > 0);
        assert!(
            cell.hotspot_migration > 0.0,
            "shift storm never migrated the hotspot"
        );
        assert!(cell.ttft.p50 >= 0.0 && cell.throughput > 0.0);
    }
}
