//! Expert placement: static sharding plus dynamic replica sets Δ_r,
//! and per-rank HBM accounting ([`memory`]).
//!
//! Paper notation (§3.1): `E_r` is the set of experts *physically hosted*
//! on rank r (the static shard), `Δ_r` the redundant experts replicated
//! onto r. PROBE replicates at most `max_redundant` experts per rank per
//! layer into a double-buffered slot region (§5: 3 replicas → 6 slots).

pub mod memory;

/// Placement of one MoE layer's experts across an EP group.
#[derive(Debug, Clone, PartialEq)]
pub struct Placement {
    /// Expert-parallel group size (ranks).
    pub ep: usize,
    /// Experts in the layer.
    pub n_experts: usize,
    /// Expert -> home rank (static shard; contiguous blocks).
    home: Vec<u16>,
    /// Expert -> sorted extra ranks currently hosting a replica.
    replicas: Vec<Vec<u16>>,
    /// Per-rank count of replica slots in use.
    slots_used: Vec<usize>,
    /// Replica slot budget per rank (paper: ≤3).
    pub max_redundant: usize,
}

impl Placement {
    /// Standard sharded placement: expert e lives on rank e / (E/ep).
    pub fn sharded(ep: usize, n_experts: usize, max_redundant: usize) -> Placement {
        assert!(ep > 0 && n_experts % ep == 0, "E must divide by ep");
        let per = n_experts / ep;
        Placement {
            ep,
            n_experts,
            home: (0..n_experts).map(|e| (e / per) as u16).collect(),
            replicas: vec![Vec::new(); n_experts],
            slots_used: vec![0; ep],
            max_redundant,
        }
    }

    /// Static home shard of `expert`.
    pub fn home_rank(&self, expert: usize) -> usize {
        self.home[expert] as usize
    }

    /// All ranks hosting expert `e` (home first, then replicas).
    pub fn ranks_hosting(&self, expert: usize) -> Vec<usize> {
        let mut out = vec![self.home[expert] as usize];
        out.extend(self.replicas[expert].iter().map(|&r| r as usize));
        out
    }

    /// Allocation-free variant of [`Self::ranks_hosting`]: iterates the home
    /// rank followed by each replica rank, in the same order.
    pub fn hosts_iter(&self, expert: usize) -> impl Iterator<Item = usize> + '_ {
        std::iter::once(self.home[expert] as usize)
            .chain(self.replicas[expert].iter().map(|&r| r as usize))
    }

    /// True when `rank` holds a copy of `expert` (home or replica).
    pub fn hosts(&self, expert: usize, rank: usize) -> bool {
        self.home[expert] as usize == rank
            || self.replicas[expert].contains(&(rank as u16))
    }

    /// Experts natively sharded to `rank`.
    pub fn native_experts(&self, rank: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| self.home[e] as usize == rank)
            .collect()
    }

    /// Redundant experts currently replicated on `rank` (Δ_r).
    pub fn replica_experts(&self, rank: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| self.replicas[e].contains(&(rank as u16)))
            .collect()
    }

    /// Replica slots in use on `rank`.
    pub fn slots_used(&self, rank: usize) -> usize {
        self.slots_used[rank]
    }

    /// Replica slots still free on `rank`.
    pub fn slots_free(&self, rank: usize) -> usize {
        self.max_redundant.saturating_sub(self.slots_used[rank])
    }

    /// Try to add a replica of `expert` on `rank`. Fails when the rank
    /// already hosts the expert or has no free slot.
    pub fn add_replica(&mut self, expert: usize, rank: usize) -> Result<(), PlacementError> {
        if self.hosts(expert, rank) {
            return Err(PlacementError::AlreadyHosted { expert, rank });
        }
        if self.slots_free(rank) == 0 {
            return Err(PlacementError::NoSlot { rank });
        }
        self.replicas[expert].push(rank as u16);
        self.replicas[expert].sort_unstable();
        self.slots_used[rank] += 1;
        Ok(())
    }

    /// Remove a replica (not the home copy).
    pub fn remove_replica(&mut self, expert: usize, rank: usize) -> Result<(), PlacementError> {
        let pos = self.replicas[expert]
            .iter()
            .position(|&r| r as usize == rank)
            .ok_or(PlacementError::NotReplica { expert, rank })?;
        self.replicas[expert].remove(pos);
        self.slots_used[rank] -= 1;
        Ok(())
    }

    /// Drop all replicas (cyclic slot reuse between layers/steps).
    pub fn clear_replicas(&mut self) {
        for r in &mut self.replicas {
            r.clear();
        }
        self.slots_used.iter_mut().for_each(|s| *s = 0);
    }

    /// Total replicas currently placed.
    pub fn total_replicas(&self) -> usize {
        self.slots_used.iter().sum()
    }

    /// Extra HBM bytes consumed by replicas on the heaviest rank, given
    /// per-expert weight bytes. Doubled for the double-buffered region.
    pub fn replica_hbm_bytes(&self, expert_bytes: f64, double_buffered: bool) -> f64 {
        let worst = self.slots_used.iter().copied().max().unwrap_or(0) as f64;
        let mult = if double_buffered { 2.0 } else { 1.0 };
        worst * expert_bytes * mult
    }

    /// Structural invariants (used by property tests).
    pub fn validate(&self) -> Result<(), PlacementError> {
        let mut used = vec![0usize; self.ep];
        for e in 0..self.n_experts {
            let mut seen = vec![self.home[e]];
            for &r in &self.replicas[e] {
                if seen.contains(&r) {
                    return Err(PlacementError::AlreadyHosted {
                        expert: e,
                        rank: r as usize,
                    });
                }
                seen.push(r);
                used[r as usize] += 1;
            }
        }
        if used != self.slots_used {
            return Err(PlacementError::SlotAccounting);
        }
        for (r, &u) in used.iter().enumerate() {
            if u > self.max_redundant {
                return Err(PlacementError::NoSlot { rank: r });
            }
        }
        Ok(())
    }
}

/// Placement mutation / invariant failures.
#[derive(Debug, Clone, PartialEq)]
pub enum PlacementError {
    /// The rank already holds a copy of the expert.
    AlreadyHosted {
        /// Expert involved.
        expert: usize,
        /// Rank involved.
        rank: usize,
    },
    /// The rank's replica-slot budget is exhausted.
    NoSlot {
        /// Rank involved.
        rank: usize,
    },
    /// Attempted to remove a replica that does not exist.
    NotReplica {
        /// Expert involved.
        expert: usize,
        /// Rank involved.
        rank: usize,
    },
    /// Internal per-rank slot counters diverged from the replica sets.
    SlotAccounting,
}

impl std::fmt::Display for PlacementError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementError::AlreadyHosted { expert, rank } => {
                write!(f, "expert {expert} already hosted on rank {rank}")
            }
            PlacementError::NoSlot { rank } => {
                write!(f, "no replica slot free on rank {rank}")
            }
            PlacementError::NotReplica { expert, rank } => {
                write!(f, "expert {expert} has no replica on rank {rank}")
            }
            PlacementError::SlotAccounting => write!(f, "slot accounting mismatch"),
        }
    }
}

impl std::error::Error for PlacementError {}

/// Difference between two placements: per-rank prefetch/evict sets
/// (paper Δ_r^in / Δ_r^out), used to cost expert transfers (eq. 6).
#[derive(Debug, Clone, Default)]
pub struct PlacementDelta {
    /// (rank, experts to fetch into its replica region)
    pub fetch: Vec<Vec<usize>>,
    /// (rank, experts evicted)
    pub evict: Vec<Vec<usize>>,
}

impl PlacementDelta {
    /// Per-rank fetch/evict sets turning `old` into `new`.
    pub fn between(old: &Placement, new: &Placement) -> PlacementDelta {
        assert_eq!(old.ep, new.ep);
        let mut fetch = vec![Vec::new(); new.ep];
        let mut evict = vec![Vec::new(); new.ep];
        for r in 0..new.ep {
            let o = old.replica_experts(r);
            let n = new.replica_experts(r);
            for &e in &n {
                if !o.contains(&e) {
                    fetch[r].push(e);
                }
            }
            for &e in &o {
                if !n.contains(&e) {
                    evict[r].push(e);
                }
            }
        }
        PlacementDelta { fetch, evict }
    }

    /// max(|Δ_in|, |Δ_out|) for rank r (paper eq. 6 numerator count).
    pub fn transfer_slots(&self, rank: usize) -> usize {
        self.fetch[rank].len().max(self.evict[rank].len())
    }

    /// True when the two placements are identical.
    pub fn is_empty(&self) -> bool {
        self.fetch.iter().all(|f| f.is_empty()) && self.evict.iter().all(|e| e.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sharded_contiguous() {
        let p = Placement::sharded(4, 16, 3);
        assert_eq!(p.home_rank(0), 0);
        assert_eq!(p.home_rank(3), 0);
        assert_eq!(p.home_rank(4), 1);
        assert_eq!(p.home_rank(15), 3);
        assert_eq!(p.native_experts(2), vec![8, 9, 10, 11]);
    }

    #[test]
    fn add_remove_replica() {
        let mut p = Placement::sharded(4, 16, 2);
        p.add_replica(0, 3).unwrap();
        assert!(p.hosts(0, 3));
        assert_eq!(p.ranks_hosting(0), vec![0, 3]);
        assert_eq!(p.slots_used(3), 1);
        p.remove_replica(0, 3).unwrap();
        assert!(!p.hosts(0, 3));
        assert_eq!(p.slots_used(3), 0);
        p.validate().unwrap();
    }

    #[test]
    fn slot_budget_enforced() {
        let mut p = Placement::sharded(4, 16, 1);
        p.add_replica(0, 1).unwrap();
        // expert 8 homes on rank 2; rank 1's single slot is taken
        assert_eq!(
            p.add_replica(8, 1).unwrap_err(),
            PlacementError::NoSlot { rank: 1 }
        );
    }

    #[test]
    fn no_duplicate_hosting() {
        let mut p = Placement::sharded(4, 16, 2);
        assert!(p.add_replica(0, 0).is_err()); // home rank
        p.add_replica(0, 1).unwrap();
        assert!(p.add_replica(0, 1).is_err()); // already replicated
    }

    #[test]
    fn clear_resets_slots() {
        let mut p = Placement::sharded(2, 4, 3);
        p.add_replica(0, 1).unwrap();
        p.add_replica(2, 0).unwrap();
        p.clear_replicas();
        assert_eq!(p.total_replicas(), 0);
        assert_eq!(p.replica_experts(0), Vec::<usize>::new());
        p.validate().unwrap();
    }

    #[test]
    fn delta_between_placements() {
        let old = Placement::sharded(2, 4, 3);
        let mut new = old.clone();
        new.add_replica(0, 1).unwrap();
        new.add_replica(3, 0).unwrap();
        let d = PlacementDelta::between(&old, &new);
        assert_eq!(d.fetch[1], vec![0]);
        assert_eq!(d.fetch[0], vec![3]);
        assert!(d.evict.iter().all(|e| e.is_empty()));
        assert_eq!(d.transfer_slots(1), 1);
        assert!(!d.is_empty());
        assert!(PlacementDelta::between(&old, &old).is_empty());
    }

    #[test]
    fn replica_hbm_accounting() {
        let mut p = Placement::sharded(2, 4, 3);
        p.add_replica(0, 1).unwrap();
        p.add_replica(1, 1).unwrap();
        assert_eq!(p.replica_hbm_bytes(10.0, false), 20.0);
        assert_eq!(p.replica_hbm_bytes(10.0, true), 40.0);
    }
}
