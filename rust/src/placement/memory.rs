//! Per-rank HBM accounting: why static per-layer replication (EPLB) OOMs
//! under prefill memory pressure while PROBE's cyclically-reused replica
//! buffer does not (paper §6.2 / Fig. 7 exclusion note), plus the live
//! [`MemoryManager`] the serving engine admits every mixed batch
//! through (ISSUE 5).
//!
//! EPLB reserves `slots × n_layers` expert placeholders per rank (every
//! layer keeps its replicas resident). PROBE double-buffers a single
//! region of `2 × max_redundant` slots reused across layers (§5: 3
//! replicas → 6 slots per device), leaving the capacity to the KV cache.
//!
//! The static functions below answer "does a configuration fit"; the
//! [`MemoryManager`] answers the same question *continuously* while the
//! engine serves: KV pages grow with decode progress, the activation
//! watermark follows the step's in-flight tokens, and the replica-slot
//! headroom published to the balancer shrinks as KV pressure rises —
//! the co-balancing tension the paper's hardware-aware solver encodes.

use crate::model::MoeModel;
use crate::topology::HardwareProfile;

/// Bytes breakdown for one rank.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryBreakdown {
    /// Resident model weights (experts + non-expert share).
    pub weights: f64,
    /// Replica-region reservation under the active policy.
    pub replica_buffers: f64,
    /// Transient activation bytes for in-flight tokens.
    pub activations: f64,
    /// KV-cache reservation.
    pub kv_reserved: f64,
    /// HBM capacity of the rank.
    pub capacity: f64,
}

impl MemoryBreakdown {
    /// Total bytes consumed.
    pub fn total(&self) -> f64 {
        self.weights + self.replica_buffers + self.activations + self.kv_reserved
    }
    /// True when the breakdown fits into capacity.
    pub fn fits(&self) -> bool {
        self.total() <= self.capacity
    }
    /// HBM left for KV cache beyond the reservation.
    pub fn headroom(&self) -> f64 {
        self.capacity - self.total()
    }
}

/// Replication policy memory shapes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReplicaPolicy {
    /// No replication (static sharded EP).
    None,
    /// Static per-layer placeholders: `slots` resident replicas per rank
    /// on EVERY layer (EPLB).
    StaticPerLayer {
        /// Replica slots per rank per layer.
        slots: usize,
    },
    /// One double-buffered region reused across layers (PROBE):
    /// `2 × max_redundant` expert slots total.
    CyclicBuffer {
        /// Replica slots per rank (doubled for the two buffers).
        max_redundant: usize,
    },
}

impl ReplicaPolicy {
    /// HBM bytes the policy reserves per rank.
    pub fn bytes(&self, model: &MoeModel) -> f64 {
        let w = model.expert_param_bytes();
        match self {
            ReplicaPolicy::None => 0.0,
            ReplicaPolicy::StaticPerLayer { slots } => {
                *slots as f64 * model.n_layers as f64 * w
            }
            ReplicaPolicy::CyclicBuffer { max_redundant } => 2.0 * *max_redundant as f64 * w,
        }
    }
}

/// Attention KV bytes per token per rank (GQA group of 8, both K and V,
/// all layers; heads sharded with DP attention so the whole token's KV
/// lives on its rank).
pub fn kv_bytes_per_token(model: &MoeModel) -> f64 {
    let gqa = 8.0;
    2.0 * (model.hidden as f64 / gqa) * model.dtype_bytes * model.n_layers as f64
}

/// Transient activation bytes for `tokens_in_flight` (prefill chunk):
/// residual stream + MoE dispatch buffers ≈ 6 live tensors of [T, H].
pub fn activation_bytes(model: &MoeModel, tokens_in_flight: usize) -> f64 {
    6.0 * tokens_in_flight as f64 * model.hidden as f64 * model.dtype_bytes
}

/// Resident model weight bytes per rank: MoE expert shards plus the
/// non-expert (attention etc.) share, approximated as 15% of the expert
/// mass. Shared by [`rank_memory`] and the live [`MemoryManager`].
pub fn weights_per_rank(model: &MoeModel, ep: usize) -> f64 {
    let experts = model.n_experts as f64 / ep.max(1) as f64
        * model.n_layers as f64
        * model.expert_param_bytes();
    experts * 1.15
}

/// Build the per-rank breakdown for a serving configuration.
pub fn rank_memory(
    model: &MoeModel,
    hw: &HardwareProfile,
    ep: usize,
    policy: ReplicaPolicy,
    prefill_tokens_per_rank: usize,
    kv_tokens_per_rank: usize,
) -> MemoryBreakdown {
    MemoryBreakdown {
        weights: weights_per_rank(model, ep),
        replica_buffers: policy.bytes(model),
        activations: activation_bytes(model, prefill_tokens_per_rank),
        kv_reserved: kv_tokens_per_rank as f64 * kv_bytes_per_token(model),
        capacity: hw.hbm_capacity,
    }
}

/// Max KV tokens a rank can hold under a policy (the capacity the
/// replica policy *costs*).
pub fn max_kv_tokens(
    model: &MoeModel,
    hw: &HardwareProfile,
    ep: usize,
    policy: ReplicaPolicy,
    prefill_tokens_per_rank: usize,
) -> f64 {
    let b = rank_memory(model, hw, ep, policy, prefill_tokens_per_rank, 0);
    (b.headroom() / kv_bytes_per_token(model)).max(0.0)
}

/// Live per-rank HBM governor for the memory-checked continuous-batching
/// step model (ISSUE 5).
///
/// The serving engine threads every [`crate::engine::BatchComposition`]
/// through one of these before execution:
/// * **KV pages** — each admitted request's KV lives on one rank
///   (DP attention; see [`kv_bytes_per_token`]) and grows by one row per
///   decode step and by the chunk size per prefill chunk.
/// * **Activation watermark** — the transient in-flight bytes of the
///   current step's tokens ([`activation_bytes`]), shared evenly by all
///   ranks.
/// * **Replica headroom** — how many expert-replica slots still fit in
///   each rank's free HBM *after* weights + activations + KV. Replicas
///   are the lowest-priority tenant (eviction is a free overwrite), so
///   admission never charges them; instead the published
///   [`MemoryManager::replica_caps`] shrink as KV pressure rises and the
///   planner bounds replication by them.
///
/// `slot_cost` encodes the policy's reservation shape: PROBE's cyclic
/// double buffer costs `2 × W` per redundant expert regardless of depth;
/// EPLB's static per-layer placeholders cost `n_layers × W` per slot —
/// which is why its caps collapse first under memory pressure (the
/// paper's Fig. 7 exclusion, now live).
#[derive(Debug, Clone)]
pub struct MemoryManager {
    model: MoeModel,
    ep: usize,
    capacity: f64,
    weights: f64,
    max_slots: usize,
    slot_cost: f64,
    /// Fixed activation reservation the replica pool is sized against:
    /// the engine's peak per-step watermark (token budget). Using the
    /// peak instead of the live watermark keeps the replica caps a pure
    /// function of KV pressure — monotonically shrinking while KV grows
    /// — and guarantees a prefill-heavy step never OOMs into space a
    /// replica was granted from.
    act_reserve: f64,
    kv_bpt: f64,
    kv_tokens: Vec<f64>,
    step_tokens: usize,
    enforce: bool,
}

impl MemoryManager {
    /// Governor over `ep` ranks of `capacity` bytes each serving `model`.
    /// `max_slots` is the policy's replica budget per rank, `slot_cost`
    /// the HBM bytes one granted slot reserves, `act_reserve_tokens`
    /// the peak per-step token watermark the replica pool must leave
    /// room for (the engine's step token budget); `enforce = false`
    /// turns the governor into a pass-through (admit everything,
    /// publish the full `max_slots`) for ablations.
    pub fn new(
        model: &MoeModel,
        ep: usize,
        capacity: f64,
        max_slots: usize,
        slot_cost: f64,
        act_reserve_tokens: usize,
        enforce: bool,
    ) -> MemoryManager {
        let ep = ep.max(1);
        MemoryManager {
            model: model.clone(),
            ep,
            capacity,
            weights: weights_per_rank(model, ep),
            max_slots,
            slot_cost,
            act_reserve: activation_bytes(model, act_reserve_tokens.div_ceil(ep)),
            kv_bpt: kv_bytes_per_token(model),
            kv_tokens: vec![0.0; ep],
            step_tokens: 0,
            enforce,
        }
    }

    /// Whether admission checks and headroom caps are live.
    pub fn enforced(&self) -> bool {
        self.enforce
    }

    /// The policy's replica-slot budget per rank (the cap ceiling).
    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// KV rows currently resident on `rank`.
    pub fn kv_tokens(&self, rank: usize) -> f64 {
        self.kv_tokens[rank]
    }

    /// KV rows resident across all ranks.
    pub fn total_kv_tokens(&self) -> f64 {
        self.kv_tokens.iter().sum()
    }

    /// Transient activation bytes of the current step's watermark.
    fn activations(&self) -> f64 {
        activation_bytes(&self.model, self.step_tokens.div_ceil(self.ep))
    }

    /// HBM left on `rank` after weights, the fixed peak-activation
    /// reservation, and resident KV — the pool replica slots are
    /// granted from. A pure function of KV pressure, so it only shrinks
    /// while KV grows.
    pub fn free_bytes(&self, rank: usize) -> f64 {
        self.capacity - self.weights - self.act_reserve - self.kv_tokens[rank] * self.kv_bpt
    }

    /// Fraction of the rank's post-weights capacity consumed by KV.
    pub fn kv_occupancy(&self, rank: usize) -> f64 {
        let pool = (self.capacity - self.weights).max(1.0);
        (self.kv_tokens[rank] * self.kv_bpt / pool).clamp(0.0, 1.0)
    }

    /// Replica slots still grantable on `rank` under the live headroom
    /// (the planner's per-rank bound). Monotonically non-increasing
    /// while KV grows.
    pub fn replica_cap(&self, rank: usize) -> usize {
        if !self.enforce || self.slot_cost <= 0.0 {
            return self.max_slots;
        }
        ((self.free_bytes(rank).max(0.0) / self.slot_cost) as usize).min(self.max_slots)
    }

    /// [`MemoryManager::replica_cap`] for every rank.
    pub fn replica_caps(&self) -> Vec<usize> {
        (0..self.ep).map(|r| self.replica_cap(r)).collect()
    }

    /// Allocation-free governor snapshot for the flight recorder's
    /// `MemGovernor` events: `(resident KV rows, step token watermark,
    /// min per-rank replica cap)`.
    pub fn telemetry_snapshot(&self) -> (f64, usize, usize) {
        let cap_min = (0..self.ep)
            .map(|r| self.replica_cap(r))
            .min()
            .unwrap_or(0);
        (self.total_kv_tokens(), self.step_tokens, cap_min)
    }

    /// Full bytes breakdown of `rank` with the replica region at its
    /// currently-granted cap. By construction a breakdown built from an
    /// admitted state always satisfies [`MemoryBreakdown::fits`]: the
    /// cap is derived from the free bytes the other tenants left.
    pub fn breakdown(&self, rank: usize) -> MemoryBreakdown {
        MemoryBreakdown {
            weights: self.weights,
            replica_buffers: self.replica_cap(rank) as f64 * self.slot_cost,
            activations: self.activations(),
            kv_reserved: self.kv_tokens[rank] * self.kv_bpt,
            capacity: self.capacity,
        }
    }

    /// Admission check: would `rank` still fit with `extra_kv` more KV
    /// rows under a step watermark of `step_tokens` in-flight tokens?
    /// (Replica buffers are not charged — they yield to KV for free.)
    pub fn fits_extra(&self, rank: usize, extra_kv: usize, step_tokens: usize) -> bool {
        if !self.enforce {
            return true;
        }
        let act = activation_bytes(&self.model, step_tokens.div_ceil(self.ep));
        self.weights + act + (self.kv_tokens[rank] + extra_kv as f64) * self.kv_bpt
            <= self.capacity
    }

    /// Rank with the most KV headroom (ties pick the lowest index) —
    /// where a newly admitted request's KV pages land.
    pub fn least_loaded_rank(&self) -> usize {
        let mut best = 0;
        for r in 1..self.ep {
            if self.kv_tokens[r] < self.kv_tokens[best] {
                best = r;
            }
        }
        best
    }

    /// Ranks the governor accounts for.
    pub fn ranks(&self) -> usize {
        self.ep
    }

    /// Pick the KV home rank for a new admission: the least-loaded rank
    /// (counting `pending` provisional rows from admissions earlier in
    /// the same step) that still fits `extra_kv` more rows under a
    /// `step_tokens` activation watermark. `None` when no rank fits.
    pub fn admit_rank(
        &self,
        extra_kv: usize,
        step_tokens: usize,
        pending: &[usize],
    ) -> Option<usize> {
        let load = |r: usize| self.kv_tokens[r] + pending.get(r).copied().unwrap_or(0) as f64;
        let mut best: Option<usize> = None;
        for r in 0..self.ep {
            let pend = pending.get(r).copied().unwrap_or(0);
            if !self.fits_extra(r, extra_kv + pend, step_tokens) {
                continue;
            }
            if best.map_or(true, |b| load(r) < load(b)) {
                best = Some(r);
            }
        }
        best
    }

    /// Record the current step's activation watermark (total in-flight
    /// prefill + decode tokens of the composed batch).
    pub fn set_step_tokens(&mut self, tokens: usize) {
        self.step_tokens = tokens;
    }

    /// Commit `tokens` more KV rows onto `rank` (prefill chunk or
    /// decode progress).
    pub fn grow(&mut self, rank: usize, tokens: usize) {
        self.kv_tokens[rank] += tokens as f64;
    }

    /// Release `tokens` KV rows from `rank` (retirement or preemption).
    pub fn release(&mut self, rank: usize, tokens: usize) {
        self.kv_tokens[rank] = (self.kv_tokens[rank] - tokens as f64).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (MoeModel, HardwareProfile) {
        (MoeModel::gpt_oss_120b(), HardwareProfile::hopper_141())
    }

    #[test]
    fn weights_fit_without_replication() {
        let (m, hw) = setup();
        let b = rank_memory(&m, &hw, 8, ReplicaPolicy::None, 8192, 0);
        assert!(b.fits(), "base weights must fit: {b:?}");
        // GPT-OSS-120B: ~27GB expert weights per rank at ep=8
        assert!(b.weights > 20e9 && b.weights < 40e9, "{}", b.weights);
    }

    #[test]
    fn eplb_static_placeholders_cost_layers_times_slots() {
        let (m, _) = setup();
        let eplb = ReplicaPolicy::StaticPerLayer { slots: 2 }.bytes(&m);
        let probe = ReplicaPolicy::CyclicBuffer { max_redundant: 3 }.bytes(&m);
        // 2 slots x 36 layers vs 6 slots total
        assert!((eplb / probe - (2.0 * 36.0) / 6.0).abs() < 1e-9);
        assert!(eplb > 3e9, "EPLB reservation should be GBs: {eplb}");
        assert!(probe < 0.4e9, "PROBE buffer should be ~285MB x2: {probe}");
    }

    #[test]
    fn eplb_sacrifices_kv_capacity() {
        let (m, hw) = setup();
        let kv_none = max_kv_tokens(&m, &hw, 8, ReplicaPolicy::None, 0);
        let kv_eplb = max_kv_tokens(&m, &hw, 8, ReplicaPolicy::StaticPerLayer { slots: 2 }, 0);
        let kv_probe =
            max_kv_tokens(&m, &hw, 8, ReplicaPolicy::CyclicBuffer { max_redundant: 3 }, 0);
        assert!(kv_eplb < kv_probe);
        assert!(kv_probe > 0.98 * kv_none, "PROBE nearly preserves KV capacity");
        // EPLB loses a material fraction of KV room
        assert!(
            (kv_none - kv_eplb) / kv_none > 0.02,
            "EPLB KV loss too small: {} vs {}",
            kv_eplb,
            kv_none
        );
    }

    #[test]
    fn prefill_pressure_can_oom_eplb_but_not_probe() {
        // the Fig. 7 exclusion: large-batch prefill (activations + in-
        // flight KV) plus EPLB's static placeholders exceeds capacity.
        let (m, hw) = setup();
        let prefill_tokens = 16384; // 16K tokens per rank in flight
        // KV pool sized to 98% of what PROBE's cyclic buffer leaves free:
        // fits under PROBE, exceeds capacity under EPLB's static
        // per-layer placeholders (the ~3.1 GB/rank difference).
        let kv_tokens = (0.98
            * max_kv_tokens(
                &m, &hw, 8,
                ReplicaPolicy::CyclicBuffer { max_redundant: 3 },
                prefill_tokens,
            )) as usize;
        let eplb = rank_memory(
            &m, &hw, 8,
            ReplicaPolicy::StaticPerLayer { slots: 2 },
            prefill_tokens, kv_tokens,
        );
        let probe = rank_memory(
            &m, &hw, 8,
            ReplicaPolicy::CyclicBuffer { max_redundant: 3 },
            prefill_tokens, kv_tokens,
        );
        assert!(!eplb.fits(), "EPLB should OOM here: {:?}", eplb.total());
        assert!(probe.fits(), "PROBE must fit: {:?}", probe.total());
    }

    #[test]
    fn kv_bytes_scale_with_layers() {
        let (m, _) = setup();
        let q = MoeModel::qwen3_235b();
        assert!(kv_bytes_per_token(&q) > kv_bytes_per_token(&m));
    }

    #[test]
    fn manager_caps_shrink_as_kv_grows_and_breakdown_always_fits() {
        let (m, _) = setup();
        let w = m.expert_param_bytes();
        // capacity = weights + room for 3 double-buffered slots + some KV
        let cap = weights_per_rank(&m, 8) + 3.0 * 2.0 * w + 40_000.0 * kv_bytes_per_token(&m);
        let mut mm = MemoryManager::new(&m, 8, cap, 3, 2.0 * w, 0, true);
        assert_eq!(mm.replica_cap(0), 3);
        assert!(mm.breakdown(0).fits());
        let mut last = mm.replica_cap(0);
        // grow to 45k rows: inside the pool (so the breakdown always
        // fits) but past the point where the last replica slot fits
        for _ in 0..9 {
            mm.grow(0, 5_000);
            let cap_now = mm.replica_cap(0);
            assert!(cap_now <= last, "cap rose while KV grew: {last} -> {cap_now}");
            assert!(mm.breakdown(0).fits(), "{:?}", mm.breakdown(0));
            last = cap_now;
        }
        assert_eq!(last, 0, "caps should exhaust under KV pressure");
        // release restores headroom
        mm.release(0, 45_000);
        assert_eq!(mm.replica_cap(0), 3);
    }

    #[test]
    fn manager_admission_respects_capacity_and_watermark() {
        let (m, _) = setup();
        let cap = weights_per_rank(&m, 8) + 10_000.0 * kv_bytes_per_token(&m);
        let mut mm = MemoryManager::new(&m, 8, cap, 3, 0.0, 0, true);
        assert!(mm.fits_extra(0, 9_000, 0));
        assert!(!mm.fits_extra(0, 11_000, 0));
        // a big activation watermark eats the same pool
        let big_step = 4 * 1024 * 1024;
        assert!(!mm.fits_extra(0, 9_000, big_step));
        // committed KV moves the line
        mm.grow(0, 8_000);
        assert!(!mm.fits_extra(0, 4_000, 0));
        assert!(mm.fits_extra(1, 9_000, 0), "other ranks unaffected");
        assert_eq!(mm.least_loaded_rank(), 1);
        // pass-through mode admits anything and publishes the full budget
        let off = MemoryManager::new(&m, 8, cap, 3, 2.0 * m.expert_param_bytes(), 0, false);
        assert!(off.fits_extra(0, usize::MAX / 2, 0));
        assert_eq!(off.replica_cap(0), 3);
    }

    #[test]
    fn per_layer_slot_cost_collapses_before_cyclic() {
        // EPLB-shaped reservations (n_layers x W per slot) run out of
        // headroom long before PROBE's cyclic buffer does
        let (m, _) = setup();
        let w = m.expert_param_bytes();
        let cap = weights_per_rank(&m, 8) + 8.0 * w;
        let probe = MemoryManager::new(&m, 8, cap, 3, 2.0 * w, 0, true);
        let eplb = MemoryManager::new(&m, 8, cap, 2, m.n_layers as f64 * w, 0, true);
        assert_eq!(probe.replica_cap(0), 3);
        assert_eq!(eplb.replica_cap(0), 0);
    }
}
