//! Per-rank HBM accounting: why static per-layer replication (EPLB) OOMs
//! under prefill memory pressure while PROBE's cyclically-reused replica
//! buffer does not (paper §6.2 / Fig. 7 exclusion note).
//!
//! EPLB reserves `slots × n_layers` expert placeholders per rank (every
//! layer keeps its replicas resident). PROBE double-buffers a single
//! region of `2 × max_redundant` slots reused across layers (§5: 3
//! replicas → 6 slots per device), leaving the capacity to the KV cache.

use crate::model::MoeModel;
use crate::topology::HardwareProfile;

/// Bytes breakdown for one rank.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryBreakdown {
    /// Resident model weights (experts + non-expert share).
    pub weights: f64,
    /// Replica-region reservation under the active policy.
    pub replica_buffers: f64,
    /// Transient activation bytes for in-flight tokens.
    pub activations: f64,
    /// KV-cache reservation.
    pub kv_reserved: f64,
    /// HBM capacity of the rank.
    pub capacity: f64,
}

impl MemoryBreakdown {
    /// Total bytes consumed.
    pub fn total(&self) -> f64 {
        self.weights + self.replica_buffers + self.activations + self.kv_reserved
    }
    /// True when the breakdown fits into capacity.
    pub fn fits(&self) -> bool {
        self.total() <= self.capacity
    }
    /// HBM left for KV cache beyond the reservation.
    pub fn headroom(&self) -> f64 {
        self.capacity - self.total()
    }
}

/// Replication policy memory shapes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReplicaPolicy {
    /// No replication (static sharded EP).
    None,
    /// Static per-layer placeholders: `slots` resident replicas per rank
    /// on EVERY layer (EPLB).
    StaticPerLayer {
        /// Replica slots per rank per layer.
        slots: usize,
    },
    /// One double-buffered region reused across layers (PROBE):
    /// `2 × max_redundant` expert slots total.
    CyclicBuffer {
        /// Replica slots per rank (doubled for the two buffers).
        max_redundant: usize,
    },
}

impl ReplicaPolicy {
    /// HBM bytes the policy reserves per rank.
    pub fn bytes(&self, model: &MoeModel) -> f64 {
        let w = model.expert_param_bytes();
        match self {
            ReplicaPolicy::None => 0.0,
            ReplicaPolicy::StaticPerLayer { slots } => {
                *slots as f64 * model.n_layers as f64 * w
            }
            ReplicaPolicy::CyclicBuffer { max_redundant } => 2.0 * *max_redundant as f64 * w,
        }
    }
}

/// Attention KV bytes per token per rank (GQA group of 8, both K and V,
/// all layers; heads sharded with DP attention so the whole token's KV
/// lives on its rank).
pub fn kv_bytes_per_token(model: &MoeModel) -> f64 {
    let gqa = 8.0;
    2.0 * (model.hidden as f64 / gqa) * model.dtype_bytes * model.n_layers as f64
}

/// Transient activation bytes for `tokens_in_flight` (prefill chunk):
/// residual stream + MoE dispatch buffers ≈ 6 live tensors of [T, H].
pub fn activation_bytes(model: &MoeModel, tokens_in_flight: usize) -> f64 {
    6.0 * tokens_in_flight as f64 * model.hidden as f64 * model.dtype_bytes
}

/// Build the per-rank breakdown for a serving configuration.
pub fn rank_memory(
    model: &MoeModel,
    hw: &HardwareProfile,
    ep: usize,
    policy: ReplicaPolicy,
    prefill_tokens_per_rank: usize,
    kv_tokens_per_rank: usize,
) -> MemoryBreakdown {
    // MoE expert weights per rank + non-expert (attention etc.) share,
    // approximated as 15% of the expert mass.
    let experts = model.n_experts as f64 / ep as f64
        * model.n_layers as f64
        * model.expert_param_bytes();
    let weights = experts * 1.15;
    MemoryBreakdown {
        weights,
        replica_buffers: policy.bytes(model),
        activations: activation_bytes(model, prefill_tokens_per_rank),
        kv_reserved: kv_tokens_per_rank as f64 * kv_bytes_per_token(model),
        capacity: hw.hbm_capacity,
    }
}

/// Max KV tokens a rank can hold under a policy (the capacity the
/// replica policy *costs*).
pub fn max_kv_tokens(
    model: &MoeModel,
    hw: &HardwareProfile,
    ep: usize,
    policy: ReplicaPolicy,
    prefill_tokens_per_rank: usize,
) -> f64 {
    let b = rank_memory(model, hw, ep, policy, prefill_tokens_per_rank, 0);
    (b.headroom() / kv_bytes_per_token(model)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (MoeModel, HardwareProfile) {
        (MoeModel::gpt_oss_120b(), HardwareProfile::hopper_141())
    }

    #[test]
    fn weights_fit_without_replication() {
        let (m, hw) = setup();
        let b = rank_memory(&m, &hw, 8, ReplicaPolicy::None, 8192, 0);
        assert!(b.fits(), "base weights must fit: {b:?}");
        // GPT-OSS-120B: ~27GB expert weights per rank at ep=8
        assert!(b.weights > 20e9 && b.weights < 40e9, "{}", b.weights);
    }

    #[test]
    fn eplb_static_placeholders_cost_layers_times_slots() {
        let (m, _) = setup();
        let eplb = ReplicaPolicy::StaticPerLayer { slots: 2 }.bytes(&m);
        let probe = ReplicaPolicy::CyclicBuffer { max_redundant: 3 }.bytes(&m);
        // 2 slots x 36 layers vs 6 slots total
        assert!((eplb / probe - (2.0 * 36.0) / 6.0).abs() < 1e-9);
        assert!(eplb > 3e9, "EPLB reservation should be GBs: {eplb}");
        assert!(probe < 0.4e9, "PROBE buffer should be ~285MB x2: {probe}");
    }

    #[test]
    fn eplb_sacrifices_kv_capacity() {
        let (m, hw) = setup();
        let kv_none = max_kv_tokens(&m, &hw, 8, ReplicaPolicy::None, 0);
        let kv_eplb = max_kv_tokens(&m, &hw, 8, ReplicaPolicy::StaticPerLayer { slots: 2 }, 0);
        let kv_probe =
            max_kv_tokens(&m, &hw, 8, ReplicaPolicy::CyclicBuffer { max_redundant: 3 }, 0);
        assert!(kv_eplb < kv_probe);
        assert!(kv_probe > 0.98 * kv_none, "PROBE nearly preserves KV capacity");
        // EPLB loses a material fraction of KV room
        assert!(
            (kv_none - kv_eplb) / kv_none > 0.02,
            "EPLB KV loss too small: {} vs {}",
            kv_eplb,
            kv_none
        );
    }

    #[test]
    fn prefill_pressure_can_oom_eplb_but_not_probe() {
        // the Fig. 7 exclusion: large-batch prefill (activations + in-
        // flight KV) plus EPLB's static placeholders exceeds capacity.
        let (m, hw) = setup();
        let prefill_tokens = 16384; // 16K tokens per rank in flight
        // KV pool sized to 98% of what PROBE's cyclic buffer leaves free:
        // fits under PROBE, exceeds capacity under EPLB's static
        // per-layer placeholders (the ~3.1 GB/rank difference).
        let kv_tokens = (0.98
            * max_kv_tokens(
                &m, &hw, 8,
                ReplicaPolicy::CyclicBuffer { max_redundant: 3 },
                prefill_tokens,
            )) as usize;
        let eplb = rank_memory(
            &m, &hw, 8,
            ReplicaPolicy::StaticPerLayer { slots: 2 },
            prefill_tokens, kv_tokens,
        );
        let probe = rank_memory(
            &m, &hw, 8,
            ReplicaPolicy::CyclicBuffer { max_redundant: 3 },
            prefill_tokens, kv_tokens,
        );
        assert!(!eplb.fits(), "EPLB should OOM here: {:?}", eplb.total());
        assert!(probe.fits(), "PROBE must fit: {:?}", probe.total());
    }

    #[test]
    fn kv_bytes_scale_with_layers() {
        let (m, _) = setup();
        let q = MoeModel::qwen3_235b();
        assert!(kv_bytes_per_token(&q) > kv_bytes_per_token(&m));
    }
}
