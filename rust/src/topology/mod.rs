//! Cluster/hardware profiles (the paper's 8×Hopper-141GB testbed and
//! variants used for hardware-aware ablations).
//!
//! The discrete-event simulator consumes these constants through
//! [`crate::perfmodel`]; no real GPUs are touched (DESIGN.md
//! substitutions).

/// Per-rank hardware characteristics.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareProfile {
    /// Preset name (CLI/TOML key).
    pub name: String,
    /// Peak dense BF16 FLOP/s per rank.
    pub peak_flops: f64,
    /// HBM bandwidth per rank (bytes/s) — memory-bound floor for cold
    /// experts (weight streaming).
    pub hbm_bw: f64,
    /// Per-rank unidirectional interconnect bandwidth (bytes/s) available
    /// to All-to-All / P2P (NVSwitch fabric).
    pub net_bw: f64,
    /// Fraction of `net_bw` an All-to-All actually achieves on balanced
    /// traffic (protocol + NVSwitch efficiency; paper Fig. 5 baseline).
    pub alltoall_efficiency: f64,
    /// Fixed latency per collective (launch + rendezvous), seconds.
    pub collective_base_latency: f64,
    /// Fixed per-kernel launch overhead, seconds.
    pub kernel_launch: f64,
    /// HBM capacity per rank (bytes) — placement feasibility checks.
    pub hbm_capacity: f64,
    /// GEMM efficiency knee: tokens/expert at which grouped GEMM reaches
    /// half its asymptotic efficiency (arithmetic-intensity model).
    pub gemm_half_tokens: f64,
    /// Asymptotic grouped-GEMM efficiency (fraction of peak).
    pub gemm_max_eff: f64,
    /// GEMM tile rows: token counts are padded to this multiple.
    pub gemm_tile: usize,
}

impl HardwareProfile {
    /// Shared-field base all Hopper-class variants derive from. Named
    /// variants override the one or two fields that define them instead
    /// of restating all twelve (fabric-era profiles add per-link
    /// parameters through [`Cluster`] constructors, not new fields here).
    fn hopper_base(name: &str) -> HardwareProfile {
        HardwareProfile {
            name: name.into(),
            peak_flops: 989e12,          // H200 dense BF16
            hbm_bw: 4.8e12,              // HBM3e
            net_bw: 450e9,               // 900 GB/s bidir => 450 GB/s per dir
            alltoall_efficiency: 0.75,
            collective_base_latency: 12e-6,
            kernel_launch: 3e-6,
            hbm_capacity: 141e9,
            gemm_half_tokens: 96.0,
            gemm_max_eff: 0.80,
            gemm_tile: 64,
        }
    }

    /// The paper's testbed: 8×NVIDIA Hopper-141GB, 900 GB/s NVSwitch.
    pub fn hopper_141() -> HardwareProfile {
        Self::hopper_base("hopper-141")
    }

    /// A bandwidth-constrained variant (e.g. H800-like NVLink cap) used
    /// by the hardware-aware planning ablation: smaller hiding window per
    /// byte transferred.
    pub fn hopper_lowbw() -> HardwareProfile {
        HardwareProfile {
            net_bw: 200e9,
            ..Self::hopper_base("hopper-lowbw")
        }
    }

    /// A compute-rich / bandwidth-poor profile: fast kernels shrink the
    /// overlap window (paper §2.3 "Enforcing Zero-Overhead Balancing").
    pub fn compute_heavy() -> HardwareProfile {
        HardwareProfile {
            peak_flops: 2.0e15,
            net_bw: 150e9,
            ..Self::hopper_base("compute-heavy")
        }
    }

    /// CPU-scale profile used when driving the *real* small model through
    /// PJRT in the end-to-end example; numbers match a commodity host so
    /// simulated windows are sane relative to wall-clock execution.
    pub fn cpu_host() -> HardwareProfile {
        HardwareProfile {
            peak_flops: 200e9,
            hbm_bw: 40e9,
            net_bw: 10e9,
            alltoall_efficiency: 0.8,
            collective_base_latency: 20e-6,
            kernel_launch: 2e-6,
            hbm_capacity: 32e9,
            gemm_half_tokens: 32.0,
            gemm_max_eff: 0.7,
            gemm_tile: 16,
            ..Self::hopper_base("cpu-host")
        }
    }

    /// Resolve a profile preset from its CLI/TOML name.
    pub fn by_name(name: &str) -> Option<HardwareProfile> {
        match name {
            "hopper-141" => Some(Self::hopper_141()),
            "hopper-lowbw" => Some(Self::hopper_lowbw()),
            "compute-heavy" => Some(Self::compute_heavy()),
            "cpu-host" => Some(Self::cpu_host()),
            _ => None,
        }
    }

    /// Effective All-to-All bandwidth on perfectly balanced traffic.
    pub fn effective_alltoall_bw(&self) -> f64 {
        self.net_bw * self.alltoall_efficiency
    }

    /// Intra-node link class of this profile (the NVSwitch port every
    /// rank owns), consumed by [`crate::fabric::Fabric`] constructors.
    pub fn intra_link(&self) -> LinkSpec {
        LinkSpec {
            bw: self.net_bw,
            efficiency: self.alltoall_efficiency,
            base_latency: self.collective_base_latency,
        }
    }
}

use crate::fabric::{Fabric, LinkSpec};

/// An EP cluster: `ep` identical ranks on an interconnect [`Fabric`]
/// (one node by default; multi-node via [`Cluster::multi_node`]).
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Expert-parallel group size (ranks).
    pub ep: usize,
    /// Per-rank hardware characteristics.
    pub profile: HardwareProfile,
    /// Interconnect topology the ranks communicate over.
    pub fabric: Fabric,
}

impl Cluster {
    /// Single-node cluster: the flat fabric reproduces the scalar
    /// `net_bw` model exactly, so this is the pre-fabric behavior.
    pub fn new(ep: usize, profile: HardwareProfile) -> Cluster {
        assert!(ep >= 1);
        let fabric = Fabric::flat(ep, &profile);
        Cluster { ep, profile, fabric }
    }

    /// Alias of [`Cluster::new`] that names the topology explicitly.
    pub fn flat(ep: usize, profile: HardwareProfile) -> Cluster {
        Cluster::new(ep, profile)
    }

    /// Multi-node cluster: `ep` ranks split into `nodes` equal nodes,
    /// with an explicit inter-node rail spec (`rails` per node).
    pub fn multi_node(
        ep: usize,
        nodes: usize,
        profile: HardwareProfile,
        inter: LinkSpec,
        rails: usize,
    ) -> Cluster {
        let fabric = Fabric::multi_node(ep, nodes, &profile, inter, rails);
        Cluster { ep, profile, fabric }
    }

    /// Multi-node cluster with per-rail bandwidth as a fraction of the
    /// intra-node port bandwidth (the `probe bench fabric` sweep axis).
    pub fn multi_node_ratio(
        ep: usize,
        nodes: usize,
        profile: HardwareProfile,
        inter_bw_ratio: f64,
        rails: usize,
    ) -> Cluster {
        let fabric = Fabric::multi_node_ratio(ep, nodes, &profile, inter_bw_ratio, rails);
        Cluster { ep, profile, fabric }
    }

    /// The paper's default evaluation cluster.
    pub fn paper_testbed() -> Cluster {
        Cluster::new(8, HardwareProfile::hopper_141())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for n in ["hopper-141", "hopper-lowbw", "compute-heavy", "cpu-host"] {
            assert_eq!(HardwareProfile::by_name(n).unwrap().name, n);
        }
        assert!(HardwareProfile::by_name("tpu").is_none());
    }

    #[test]
    fn paper_testbed_is_ep8_hopper() {
        let c = Cluster::paper_testbed();
        assert_eq!(c.ep, 8);
        assert_eq!(c.profile.name, "hopper-141");
        assert!(c.fabric.is_flat(), "default cluster must be single-node");
        assert_eq!(c.fabric.intra.bw, c.profile.net_bw);
    }

    #[test]
    fn multi_node_cluster_groups_ranks() {
        let c = Cluster::multi_node_ratio(32, 4, HardwareProfile::hopper_141(), 0.125, 2);
        assert_eq!(c.ep, 32);
        assert_eq!(c.fabric.n_nodes(), 4);
        assert_eq!(c.fabric.ranks_per_node, 8);
        assert!(c.fabric.inter.bw < c.fabric.intra.bw);
    }

    #[test]
    fn lowbw_only_changes_net() {
        let a = HardwareProfile::hopper_141();
        let b = HardwareProfile::hopper_lowbw();
        assert!(b.net_bw < a.net_bw);
        assert_eq!(a.peak_flops, b.peak_flops);
    }

    #[test]
    fn effective_bw_below_raw() {
        let p = HardwareProfile::hopper_141();
        assert!(p.effective_alltoall_bw() < p.net_bw);
    }
}
