//! Compatibility façade over the generic serving engine.
//!
//! The request lifecycle (admission → chunked prefill → continuous
//! decode with join/leave → retirement) is implemented exactly once, in
//! [`crate::engine::ServingEngine`]; this module keeps the historical
//! `Coordinator` / `RealCoordinator` names as type aliases over the two
//! [`crate::engine::StepExecutor`] backends.

pub use crate::engine::sim::{SimExecutor, PREFILL_EFFECTIVE_CTX};
pub use crate::engine::{
    ActiveEntry, BatchComposition, DecodeSlot, PrefillChunk, ServingEngine, StepExecutor,
    StepReport,
};

/// Continuous-batching coordinator over the simulated EP cluster
/// (paper-scale models, Figs. 7–9, 11).
pub type Coordinator = ServingEngine<SimExecutor>;

pub mod real {
    //! Real-model serving through PJRT (`examples/e2e_serving.rs`).
    pub use crate::engine::real::{ir_of_layers, FidelityAccum, RealExecutor};

    /// Continuous-batching server over the real small model.
    pub type RealCoordinator = crate::engine::ServingEngine<RealExecutor>;
}
