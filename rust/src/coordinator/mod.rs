//! The serving coordinator: continuous batching over the EP cluster.
//!
//! [`Coordinator`] drives paper-scale models through the cluster
//! simulator (Figs. 7–9, 11); [`real::RealCoordinator`] serves the small
//! real model through PJRT (`examples/e2e_serving.rs`). Both implement
//! the same request lifecycle: admission → chunked prefill → continuous
//! decode with join/leave at step boundaries → retirement.

pub mod real;

use std::collections::VecDeque;

/// Effective KV rows read per prefill query token (multi-K contexts after
/// GQA-8 sharing and flash tile reuse) vs the decode default of 64.
pub const PREFILL_EFFECTIVE_CTX: usize = 192;

use crate::balancers::{decide_step, Balancer};
use crate::config::Config;
use crate::metrics::{IrTracker, RequestMetrics, ServingMetrics};
use crate::routing::RoutingModel;
use crate::simulator::{ClusterSim, StepOutcome};
use crate::workload::Request;

/// A request being decoded.
#[derive(Debug, Clone)]
struct ActiveReq {
    req: Request,
    decoded: usize,
    midx: usize,
}

/// Continuous-batching coordinator over the simulated EP cluster.
pub struct Coordinator {
    pub cfg: Config,
    pub sim: ClusterSim,
    pub routing_model: RoutingModel,
    balancer: Box<dyn Balancer>,
    queue: VecDeque<Request>,
    active: Vec<ActiveReq>,
    pub clock: f64,
    pub metrics: ServingMetrics,
    pub ir: IrTracker,
    step_idx: usize,
}

impl Coordinator {
    pub fn new(cfg: Config, balancer: Box<dyn Balancer>, seed: u64) -> Coordinator {
        let sim = ClusterSim::new(cfg.model.clone(), cfg.cluster.clone());
        let routing_model = RoutingModel::calibrated(
            cfg.model.n_layers,
            cfg.model.n_experts,
            cfg.model.top_k,
            4,
            seed,
        );
        Coordinator {
            cfg,
            sim,
            routing_model,
            balancer,
            queue: VecDeque::new(),
            active: Vec::new(),
            clock: 0.0,
            metrics: ServingMetrics::default(),
            ir: IrTracker::new(),
            step_idx: 0,
        }
    }

    pub fn balancer_name(&self) -> &'static str {
        self.balancer.name()
    }

    /// Enqueue a request (admitted at the next step boundary once its
    /// arrival time has passed).
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests.push(RequestMetrics {
            id: req.id,
            arrival: req.arrival,
            ..Default::default()
        });
        self.queue.push_back(req);
    }

    /// Number of decode slots (tokens per step).
    pub fn decode_capacity(&self) -> usize {
        self.cfg.global_batch()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Admit arrived requests into free decode slots. Prefill is charged
    /// as chunked steps through the same balancer+simulator path.
    fn admit(&mut self) {
        while self.active.len() < self.decode_capacity() {
            let Some(front) = self.queue.front() else { break };
            if front.arrival > self.clock {
                break;
            }
            let req = self.queue.pop_front().unwrap();
            let midx = self
                .metrics
                .requests
                .iter()
                .position(|m| m.id == req.id)
                .expect("submitted");
            // chunked prefill for this request's prompt. Prefill queries
            // attend to multi-K contexts: use the larger effective-KV
            // constant (GQA + flash tile reuse) during these steps.
            let chunk = self.cfg.prefill_chunk_per_rank * self.cfg.cluster.ep;
            let decode_ctx = self.sim.mean_ctx;
            self.sim.mean_ctx = PREFILL_EFFECTIVE_CTX;
            let mut remaining = req.prompt_len;
            while remaining > 0 {
                let this = remaining.min(chunk);
                let outcome = self.run_routed_step(this.max(1), req.domain);
                self.clock += outcome.latency;
                remaining -= this;
            }
            self.sim.mean_ctx = decode_ctx;
            self.metrics.requests[midx].first_token = Some(self.clock);
            self.active.push(ActiveReq {
                req,
                decoded: 1, // the prefill emits the first token
                midx,
            });
        }
    }

    /// Route + balance + simulate one step with `tokens` tokens, all of
    /// domain mixture dominated by the active set (decode) or a single
    /// request (prefill chunk).
    fn run_routed_step(&mut self, tokens: usize, domain_hint: u16) -> StepOutcome {
        let domains: Vec<u16> = if self.active.is_empty() {
            vec![domain_hint; tokens]
        } else {
            (0..tokens)
                .map(|i| self.active[i % self.active.len()].req.domain)
                .collect()
        };
        let routing = self.routing_model.route_step(&domains);
        let decisions = decide_step(self.balancer.as_mut(), self.step_idx, &routing);
        let outcome = self.sim.run_step(&routing, &decisions);
        // rank token-load IR of the first layer (tracker keeps per step)
        if let Some(ir) = outcome.ir_per_layer.first() {
            self.ir.per_step.push(*ir);
        }
        self.step_idx += 1;
        outcome
    }

    /// One continuous-batching decode step; returns the outcome or None
    /// when nothing is active/admittable.
    pub fn decode_step(&mut self) -> Option<StepOutcome> {
        self.admit();
        if self.active.is_empty() {
            // idle: jump the clock to the next arrival if any
            if let Some(front) = self.queue.front() {
                self.clock = self.clock.max(front.arrival);
                self.admit();
            }
            if self.active.is_empty() {
                return None;
            }
        }
        let domains: Vec<u16> = self.active.iter().map(|a| a.req.domain).collect();
        let routing = self.routing_model.route_step(&domains);
        let decisions = decide_step(self.balancer.as_mut(), self.step_idx, &routing);
        let outcome = self.sim.run_step(&routing, &decisions);
        self.step_idx += 1;
        self.clock += outcome.latency;
        if let Some(ir) = outcome.ir_per_layer.first() {
            self.ir.per_step.push(*ir);
        }
        self.metrics
            .step_tokens
            .push((self.clock, self.active.len()));

        // token bookkeeping + retirement
        let mut retired = Vec::new();
        for (i, a) in self.active.iter_mut().enumerate() {
            a.decoded += 1;
            if a.decoded >= a.req.max_new_tokens {
                retired.push(i);
            }
        }
        for &i in retired.iter().rev() {
            let a = self.active.swap_remove(i);
            let m = &mut self.metrics.requests[a.midx];
            m.finished = Some(self.clock);
            m.tokens_out = a.decoded;
        }
        self.routing_model.step_drift();
        Some(outcome)
    }

    /// Run `n` decode steps (stops early when the system drains).
    pub fn run_decode_steps(&mut self, n: usize) -> Vec<StepOutcome> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.decode_step() {
                Some(o) => out.push(o),
                None => break,
            }
        }
        out
    }

    /// Measure prefill latency (TTFT component) for a prompt of
    /// `total_tokens` of `dataset` processed in chunks (Fig. 7).
    pub fn measure_prefill(&mut self, total_tokens: usize, domain: u16) -> f64 {
        let chunk = self.cfg.prefill_chunk_per_rank * self.cfg.cluster.ep;
        let decode_ctx = self.sim.mean_ctx;
        self.sim.mean_ctx = PREFILL_EFFECTIVE_CTX;
        let mut remaining = total_tokens;
        let mut latency = 0.0;
        while remaining > 0 {
            let this = remaining.min(chunk);
            let outcome = self.run_routed_step(this, domain);
            latency += outcome.latency;
            remaining -= this;
        }
        self.sim.mean_ctx = decode_ctx;
        latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancers::{Probe, StaticEp};
    use crate::config::ProbeConfig;
    use crate::workload::{Dataset, RequestGenerator, WorkloadSpec};

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.batch_per_rank = 32; // keep tests fast
        cfg.prefill_chunk_per_rank = 256;
        // shrink the model's layer count for speed; routing model follows
        cfg.model.n_layers = 3;
        cfg
    }

    fn gen(dataset: Dataset, seed: u64) -> RequestGenerator {
        let mut spec = WorkloadSpec::new(dataset, 4);
        spec.mean_prompt_len = 64;
        spec.mean_new_tokens = 8;
        RequestGenerator::new(spec, seed)
    }

    #[test]
    fn serves_requests_to_completion() {
        let cfg = small_cfg();
        let bal = Box::new(StaticEp::new(&cfg));
        let mut c = Coordinator::new(cfg, bal, 1);
        let mut g = gen(Dataset::Code, 2);
        for r in g.take(6) {
            c.submit(r);
        }
        let outs = c.run_decode_steps(64);
        assert!(!outs.is_empty());
        let done = c.metrics.requests.iter().filter(|m| m.finished.is_some()).count();
        assert!(done >= 4, "only {done} finished");
        for m in c.metrics.requests.iter().filter(|m| m.finished.is_some()) {
            assert!(m.ttft().unwrap() > 0.0);
            assert!(m.tokens_out > 0);
        }
    }

    #[test]
    fn clock_monotone_and_throughput_positive() {
        let cfg = small_cfg();
        let bal = Box::new(StaticEp::new(&cfg));
        let mut c = Coordinator::new(cfg, bal, 3);
        let mut g = gen(Dataset::Mixed, 4);
        for r in g.take(12) {
            c.submit(r);
        }
        let mut last = 0.0;
        for _ in 0..20 {
            if c.decode_step().is_none() {
                break;
            }
            assert!(c.clock >= last);
            last = c.clock;
        }
        assert!(c.metrics.throughput() > 0.0);
    }

    #[test]
    fn prefill_latency_scales_with_tokens() {
        let cfg = small_cfg();
        let bal = Box::new(StaticEp::new(&cfg));
        let mut c = Coordinator::new(cfg.clone(), bal, 5);
        let t_small = c.measure_prefill(2048, 0);
        let bal2 = Box::new(StaticEp::new(&cfg));
        let mut c2 = Coordinator::new(cfg, bal2, 5);
        let t_big = c2.measure_prefill(16384, 0);
        assert!(t_big > t_small * 2.0, "{t_small} vs {t_big}");
    }

    #[test]
    fn probe_coordinator_beats_static_on_skewed_decode() {
        let cfg = small_cfg();
        let run = |bal: Box<dyn crate::balancers::Balancer>| -> f64 {
            let mut c = Coordinator::new(small_cfg(), bal, 7);
            let mut g = gen(Dataset::Repeat, 8);
            for r in g.take(512) {
                c.submit(r);
            }
            c.run_decode_steps(12);
            c.metrics.throughput()
        };
        let thr_static = run(Box::new(StaticEp::new(&cfg)));
        let thr_probe = run(Box::new(Probe::new(&cfg, ProbeConfig::default(), 9)));
        assert!(
            thr_probe > thr_static,
            "probe {thr_probe} <= static {thr_static}"
        );
    }
}
