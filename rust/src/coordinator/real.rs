//! Real-model serving: continuous batching over the PJRT engine.
//!
//! Serves the small MoE transformer built by `python/compile` — real
//! prefill chunks, real decode steps, greedy sampling, KV-cache slot
//! management — and feeds the *real* router traces into the PROBE
//! metrics/balancer stack (IR tracking, predictor fidelity, planner
//! decisions over the virtual EP cluster). This is the mandated
//! end-to-end driver's engine (`examples/e2e_serving.rs`).

use std::collections::VecDeque;

use anyhow::Result;

use crate::metrics::{IrTracker, RequestMetrics, ServingMetrics};
use crate::predictor::{fidelity, PredFidelity};
use crate::routing::LayerRouting;
use crate::runtime::{predictions_from_decode, priors_from_decode, routing_from_decode, Engine};
use crate::util::Rng;
use crate::workload::Request;

/// A decode slot holding one active sequence.
#[derive(Debug, Clone)]
struct Slot {
    req_id: u64,
    midx: usize,
    pos: usize,
    decoded: usize,
    budget: usize,
    last_token: i32,
}

/// Per-layer accumulated predictor fidelity (Fig. 10 measured from rust).
#[derive(Debug, Clone, Default)]
pub struct FidelityAccum {
    pub trained: Vec<PredFidelity>,
    pub prior: Vec<PredFidelity>,
    pub samples: usize,
}

/// Continuous-batching server over the real model.
pub struct RealCoordinator {
    pub engine: Engine,
    batch: usize,
    kv: Vec<f32>,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(Request, Vec<i32>)>,
    pub metrics: ServingMetrics,
    pub ir: IrTracker,
    pub fidelity: FidelityAccum,
    /// Virtual EP size used for IR accounting of the real router traces.
    pub virtual_ep: usize,
    start: std::time::Instant,
    rng: Rng,
}

impl RealCoordinator {
    pub fn new(engine: Engine, virtual_ep: usize, seed: u64) -> RealCoordinator {
        let batch = engine.pick_batch(8);
        let kv = vec![0.0; engine.cfg().kv_len(batch)];
        let n_layers = engine.cfg().n_layers;
        RealCoordinator {
            engine,
            batch,
            kv,
            slots: (0..batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            metrics: ServingMetrics::default(),
            ir: IrTracker::new(),
            fidelity: FidelityAccum {
                trained: vec![PredFidelity::default(); n_layers],
                prior: vec![PredFidelity::default(); n_layers],
                samples: 0,
            },
            virtual_ep,
            start: std::time::Instant::now(),
            rng: Rng::new(seed),
        }
    }

    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Submit a request with its prompt tokens.
    pub fn submit(&mut self, req: Request, prompt: Vec<i32>) {
        self.metrics.requests.push(RequestMetrics {
            id: req.id,
            arrival: self.now(),
            ..Default::default()
        });
        self.queue.push_back((req, prompt));
    }

    /// Sample prompt tokens for a request. Uses the exact per-domain
    /// distributions the build's distillation corpus used
    /// (`artifacts/domain_dists.json`) so live routing matches the
    /// predictor's training distribution; falls back to a domain-
    /// permuted Zipf when absent.
    pub fn synth_prompt(&mut self, domain: u16, len: usize) -> Vec<i32> {
        if let Some(dist) = self.engine.domain_dist(domain) {
            let dist = dist.to_vec();
            return (0..len)
                .map(|_| self.rng.next_weighted(&dist) as i32)
                .collect();
        }
        let vocab = self.engine.cfg().vocab;
        let mut w = Rng::zipf_weights(vocab, 1.1);
        // per-domain deterministic permutation
        let mut perm_rng = Rng::new(0xD0_u64 + domain as u64);
        perm_rng.shuffle(&mut w);
        (0..len)
            .map(|_| self.rng.next_weighted(&w) as i32)
            .collect()
    }

    fn free_slots(&self) -> Vec<usize> {
        (0..self.batch).filter(|&i| self.slots[i].is_none()).collect()
    }

    pub fn active_count(&self) -> usize {
        self.batch - self.free_slots().len()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Admit queued requests into free slots via real chunked prefill.
    /// The prefill artifact runs `[Bp, S]`; each prefilled sequence's KV
    /// rows are migrated into the decode cache slot.
    pub fn admit(&mut self) -> Result<usize> {
        let cfg = self.engine.cfg().clone();
        let mut admitted = 0;
        loop {
            let free = self.free_slots();
            if free.is_empty() || self.queue.is_empty() {
                break;
            }
            let take = free.len().min(cfg.prefill_batch).min(self.queue.len());
            let group: Vec<(Request, Vec<i32>)> =
                (0..take).map(|_| self.queue.pop_front().unwrap()).collect();
            // chunked prefill over the longest prompt in the group
            let longest = group.iter().map(|(_, p)| p.len()).max().unwrap_or(0);
            let mut pkv = vec![0.0f32; cfg.kv_len(cfg.prefill_batch)];
            let mut start = 0usize;
            let mut last_logits: Vec<f32> = Vec::new();
            while start < longest {
                let s = cfg.prefill_chunk;
                let mut tokens = vec![0i32; cfg.prefill_batch * s];
                for (bi, (_, prompt)) in group.iter().enumerate() {
                    for j in 0..s {
                        let p = start + j;
                        tokens[bi * s + j] = if p < prompt.len() { prompt[p] } else { 0 };
                    }
                }
                let start_pos = vec![start as i32; cfg.prefill_batch];
                let out = self.engine.prefill_chunk(&tokens, &start_pos, &mut pkv)?;
                last_logits = out.logits_last.clone();
                // IR accounting from the real prefill routing
                self.track_prefill_ir(&out.actual_idx, cfg.n_layers, cfg.prefill_batch, s, cfg.top_k, cfg.n_experts);
                start += s;
            }
            // migrate each prefilled sequence into a decode slot
            let t_first = self.now();
            for (bi, (req, prompt)) in group.into_iter().enumerate() {
                let slot = self.free_slots()[0];
                self.migrate_kv(&pkv, bi, slot, prompt.len());
                let midx = self
                    .metrics
                    .requests
                    .iter()
                    .position(|m| m.id == req.id)
                    .expect("submitted");
                self.metrics.requests[midx].first_token = Some(t_first);
                let first_tok = if last_logits.is_empty() {
                    0
                } else {
                    argmax(&last_logits[bi * cfg.vocab..(bi + 1) * cfg.vocab]) as i32
                };
                self.slots[slot] = Some(Slot {
                    req_id: req.id,
                    midx,
                    pos: prompt.len(),
                    decoded: 1,
                    budget: req.max_new_tokens.max(1).min(cfg.max_seq - prompt.len() - 1),
                    last_token: first_tok,
                });
                admitted += 1;
            }
        }
        Ok(admitted)
    }

    fn track_prefill_ir(
        &mut self,
        actual_idx: &[i32],
        n_layers: usize,
        b: usize,
        s: usize,
        k: usize,
        n_experts: usize,
    ) {
        let per_rank_experts = n_experts.div_ceil(self.virtual_ep);
        for l in 0..n_layers {
            let mut loads = vec![0.0f64; self.virtual_ep];
            let base = l * b * s * k;
            for &e in &actual_idx[base..base + b * s * k] {
                if e >= 0 {
                    loads[(e as usize / per_rank_experts).min(self.virtual_ep - 1)] += 1.0;
                }
            }
            self.ir.push_loads(&loads);
        }
    }

    /// Copy sequence `src` of the prefill KV into decode slot `dst`.
    fn migrate_kv(&mut self, pkv: &[f32], src: usize, dst: usize, used_len: usize) {
        let cfg = self.engine.cfg();
        let (l_n, s_max, h) = (cfg.n_layers, cfg.max_seq, cfg.d_model);
        let pb = cfg.prefill_batch;
        let db = self.batch;
        let rows = used_len.min(s_max) * h;
        for l in 0..l_n {
            for kvh in 0..2 {
                let src_off = (((l * 2 + kvh) * pb) + src) * s_max * h;
                let dst_off = (((l * 2 + kvh) * db) + dst) * s_max * h;
                self.kv[dst_off..dst_off + rows].copy_from_slice(&pkv[src_off..src_off + rows]);
                // zero the tail (stale rows from a previous occupant)
                self.kv[dst_off + rows..dst_off + s_max * h].fill(0.0);
            }
        }
    }

    /// One real decode step over all active slots. Returns (#active,
    /// step wall-clock) or None when idle.
    pub fn decode_step(&mut self) -> Result<Option<(usize, f64)>> {
        let cfg = self.engine.cfg().clone();
        let active: Vec<usize> = (0..self.batch).filter(|&i| self.slots[i].is_some()).collect();
        if active.is_empty() {
            return Ok(None);
        }
        let mut tokens = vec![0i32; self.batch];
        let mut pos = vec![0i32; self.batch];
        for i in 0..self.batch {
            if let Some(slot) = &self.slots[i] {
                tokens[i] = slot.last_token;
                pos[i] = slot.pos as i32;
            }
        }
        let out = self
            .engine
            .decode_step(self.batch, &tokens, &pos, &mut self.kv)?;

        // --- metrics from the REAL router ---
        let routing = routing_from_decode(&out, &cfg);
        let per_rank_experts = cfg.n_experts.div_ceil(self.virtual_ep);
        for lr in &routing {
            let counts = lr.expert_counts();
            let loads: Vec<f64> = (0..self.virtual_ep)
                .map(|r| {
                    counts[r * per_rank_experts..(r + 1) * per_rank_experts]
                        .iter()
                        .sum::<u32>() as f64
                })
                .collect();
            self.ir.push_loads(&loads);
        }
        let preds = predictions_from_decode(&out, &cfg);
        let priors = priors_from_decode(&out, &cfg);
        for (l, (p, pr)) in preds.iter().zip(priors.iter()).enumerate() {
            if let (Some(p), Some(pr)) = (p, pr) {
                accum(&mut self.fidelity.trained[l], &fidelity(&routing[l], p));
                accum(&mut self.fidelity.prior[l], &fidelity(&routing[l], pr));
            }
        }
        self.fidelity.samples += 1;

        // --- sampling + slot bookkeeping ---
        let now = self.now();
        let mut n_active = 0;
        for i in 0..self.batch {
            let Some(slot) = &mut self.slots[i] else { continue };
            n_active += 1;
            let logits = &out.logits[i * cfg.vocab..(i + 1) * cfg.vocab];
            slot.last_token = argmax(logits) as i32;
            slot.pos += 1;
            slot.decoded += 1;
            let done = slot.decoded >= slot.budget || slot.pos + 1 >= cfg.max_seq;
            if done {
                let midx = slot.midx;
                let decoded = slot.decoded;
                self.metrics.requests[midx].finished = Some(now);
                self.metrics.requests[midx].tokens_out = decoded;
                self.slots[i] = None;
            }
        }
        self.metrics.step_tokens.push((now, n_active));
        Ok(Some((n_active, out.exec_time)))
    }

    /// Serve until all submitted requests finish (admitting continuously).
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<usize> {
        let mut steps = 0;
        while steps < max_steps {
            self.admit()?;
            match self.decode_step()? {
                Some(_) => steps += 1,
                None => {
                    if self.queue.is_empty() {
                        break;
                    }
                }
            }
        }
        Ok(steps)
    }

    /// Mean per-layer predictor fidelity accumulated so far.
    pub fn fidelity_report(&self) -> Vec<(usize, f64, f64)> {
        (1..self.engine.cfg().n_layers)
            .map(|l| {
                let t = &self.fidelity.trained[l];
                let p = &self.fidelity.prior[l];
                (l, t.top_k_accuracy, p.top_k_accuracy)
            })
            .collect()
    }
}

fn accum(into: &mut PredFidelity, f: &PredFidelity) {
    // running mean weighted by token counts
    let n0 = into.n_tokens as f64;
    let n1 = f.n_tokens as f64;
    if n0 + n1 == 0.0 {
        return;
    }
    into.top_k_accuracy = (into.top_k_accuracy * n0 + f.top_k_accuracy * n1) / (n0 + n1);
    into.top_half_k_hit_rate =
        (into.top_half_k_hit_rate * n0 + f.top_half_k_hit_rate * n1) / (n0 + n1);
    into.n_tokens += f.n_tokens;
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Routing layers joined across decode steps (used by Fig. 2 small-real
/// traces and tests).
pub fn ir_of_layers(layers: &[LayerRouting], ep: usize) -> Vec<f64> {
    layers
        .iter()
        .map(|lr| {
            let per = lr.n_experts.div_ceil(ep);
            let counts = lr.expert_counts();
            let loads: Vec<f64> = (0..ep)
                .map(|r| counts[r * per..((r + 1) * per).min(counts.len())].iter().sum::<u32>() as f64)
                .collect();
            crate::util::stats::imbalance_ratio(&loads)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn accum_weighted_mean() {
        let mut a = PredFidelity::default();
        accum(
            &mut a,
            &PredFidelity {
                top_k_accuracy: 1.0,
                top_half_k_hit_rate: 1.0,
                n_tokens: 10,
            },
        );
        accum(
            &mut a,
            &PredFidelity {
                top_k_accuracy: 0.0,
                top_half_k_hit_rate: 0.5,
                n_tokens: 10,
            },
        );
        assert!((a.top_k_accuracy - 0.5).abs() < 1e-12);
        assert!((a.top_half_k_hit_rate - 0.75).abs() < 1e-12);
        assert_eq!(a.n_tokens, 20);
    }
}
