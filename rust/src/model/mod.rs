//! MoE model descriptors for the paper-scale models the simulator serves.
//!
//! These describe *shape and cost*, not weights: per-expert parameter
//! bytes `W`, per-token FLOPs `F̄`, hidden size `H` (paper Table 1). The
//! real small model executed via PJRT is described by
//! `artifacts/metadata.json` instead (see [`crate::runtime`]).

/// Static description of an MoE model (per paper §3.1 notation).
#[derive(Debug, Clone, PartialEq)]
pub struct MoeModel {
    /// Preset name (CLI/TOML key).
    pub name: String,
    /// Number of MoE layers (dense layers are irrelevant to EP balance).
    pub n_layers: usize,
    /// Experts per MoE layer.
    pub n_experts: usize,
    /// Experts activated per token (top-k).
    pub top_k: usize,
    /// Token hidden dimension H (dispatch/combine payload per token).
    pub hidden: usize,
    /// Per-expert FFN intermediate dimension.
    pub d_ff: usize,
    /// Bytes per element (2 = bf16).
    pub dtype_bytes: f64,
    /// FFN matrices per expert (3 = SwiGLU gate/up/down, 2 = classic MLP).
    pub ffn_mats: usize,
}

impl MoeModel {
    /// GPT-OSS-120B (paper §6.1): 128 experts, top-4, 36 layers, bf16.
    pub fn gpt_oss_120b() -> MoeModel {
        MoeModel {
            name: "gpt-oss-120b".into(),
            n_layers: 36,
            n_experts: 128,
            top_k: 4,
            hidden: 2880,
            d_ff: 2880,
            dtype_bytes: 2.0,
            ffn_mats: 3,
        }
    }

    /// Qwen3-MoE-235B (paper §6.1): 128 experts, top-8, ~93 layers, bf16.
    pub fn qwen3_235b() -> MoeModel {
        MoeModel {
            name: "qwen3-235b".into(),
            n_layers: 93,
            n_experts: 128,
            top_k: 8,
            hidden: 4096,
            d_ff: 1536,
            dtype_bytes: 2.0,
            ffn_mats: 3,
        }
    }

    /// The small real model built by `python/compile` (CPU-runnable).
    pub fn small_real() -> MoeModel {
        MoeModel {
            name: "small-real".into(),
            n_layers: 6,
            n_experts: 16,
            top_k: 2,
            hidden: 128,
            d_ff: 256,
            dtype_bytes: 4.0, // f32 artifacts
            ffn_mats: 2,
        }
    }

    /// Resolve a model preset from its CLI/TOML name.
    pub fn by_name(name: &str) -> Option<MoeModel> {
        match name {
            "gpt-oss-120b" => Some(Self::gpt_oss_120b()),
            "qwen3-235b" => Some(Self::qwen3_235b()),
            "small-real" => Some(Self::small_real()),
            _ => None,
        }
    }

    /// Parameter bytes per expert, W (paper Table 1).
    pub fn expert_param_bytes(&self) -> f64 {
        self.ffn_mats as f64 * self.hidden as f64 * self.d_ff as f64 * self.dtype_bytes
    }

    /// Per-token FLOPs per expert, F̄ (2 FLOPs per MAC).
    pub fn per_token_flops(&self) -> f64 {
        2.0 * self.ffn_mats as f64 * self.hidden as f64 * self.d_ff as f64
    }

    /// Dispatch/combine payload bytes per token (hidden vector).
    pub fn token_bytes(&self) -> f64 {
        self.hidden as f64 * self.dtype_bytes
    }

    /// Experts per rank under a pure sharded placement.
    pub fn experts_per_rank(&self, ep: usize) -> usize {
        assert!(
            self.n_experts % ep == 0,
            "n_experts {} not divisible by ep {}",
            self.n_experts,
            ep
        );
        self.n_experts / ep
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_exist() {
        for name in ["gpt-oss-120b", "qwen3-235b", "small-real"] {
            let m = MoeModel::by_name(name).unwrap();
            assert_eq!(m.name, name);
        }
        assert!(MoeModel::by_name("nope").is_none());
    }

    #[test]
    fn gpt_oss_shapes_match_paper() {
        let m = MoeModel::gpt_oss_120b();
        assert_eq!((m.n_experts, m.top_k, m.n_layers), (128, 4, 36));
    }

    #[test]
    fn qwen_sparser_than_gpt_oss() {
        // paper: GPT-OSS top-4/128 is *sparser* than Qwen top-8/128
        let g = MoeModel::gpt_oss_120b();
        let q = MoeModel::qwen3_235b();
        assert!(g.top_k < q.top_k);
    }

    #[test]
    fn expert_bytes_formula() {
        let m = MoeModel::gpt_oss_120b();
        // 3 * 2880 * 2880 * 2 bytes ≈ 47.5 MiB/expert
        let w = m.expert_param_bytes();
        assert!((w - 3.0 * 2880.0 * 2880.0 * 2.0).abs() < 1.0);
        assert!(w > 40e6 && w < 60e6);
    }

    #[test]
    fn per_token_flops_positive() {
        let m = MoeModel::qwen3_235b();
        assert!((m.per_token_flops() - 2.0 * 3.0 * 4096.0 * 1536.0).abs() < 1.0);
    }

    #[test]
    fn experts_per_rank_divides() {
        assert_eq!(MoeModel::gpt_oss_120b().experts_per_rank(8), 16);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn experts_per_rank_rejects_ragged() {
        MoeModel::gpt_oss_120b().experts_per_rank(7);
    }
}
