//! Hardware-Aware Balance Planning (paper §4.3, Algorithm 1).
//!
//! Greedy rebalancing: repeatedly pair the bottleneck rank `r_src` with
//! the least-loaded rank `r_dst`, replicate `r_src`'s hottest movable
//! expert onto `r_dst` (gated by the dual-side transfer budget so the
//! prefetch hides inside the per-rank window), and redistribute that
//! expert's *remote* tokens with locality-first water-filling. Stops at
//! convergence (gain ≤ ε) or the iteration cap `k_max`.
//!
//! Two refinements over the literal Algorithm 1 (ISSUE 2):
//! * **Delta planning** (`cfg.delta_plan`): instead of clearing all
//!   replicas and re-planning from the static base every layer, the plan
//!   starts from the *resident* placement (what the previous plan for
//!   this layer left in HBM), evicts only replicas whose predicted load
//!   went cold (eviction is a free overwrite), reuses the still-hot ones
//!   at zero transfer cost, and reports only the *new* fetches in
//!   [`PlanOutcome::fetches`]. On drifting workloads the per-layer fetch
//!   volume drops to the hotspot diff.
//! * **Incremental latency state** ([`LatencyState`]): the greedy loop
//!   updates per-rank compute/traffic terms as flows shift instead of
//!   recomputing the full O(E·ep²) [`rank_latencies`] per iteration.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use crate::config::ProbeConfig;
use crate::fabric::{Fabric, Flow};
use crate::model::MoeModel;
use crate::perfmodel::{expert_compute_time, transfer_time, Assignment, ShiftUndo};
use crate::placement::Placement;
use crate::topology::HardwareProfile;

/// Result of one planning invocation (one layer, one step).
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    /// Planned placement for the target layer.
    pub placement: Placement,
    /// Token assignment over the predicted counts.
    pub assignment: Assignment,
    /// Experts NEWLY fetched per rank this plan (|Δ_r^in| minus reuse).
    pub fetches: Vec<Vec<usize>>,
    /// Routed source→destination transfer flows behind `fetches` (one
    /// per fetched expert; source chosen topology-aware when enabled).
    pub fetch_flows: Vec<Flow>,
    /// Resident replicas reused at zero transfer cost (delta planning).
    pub retained_replicas: usize,
    /// Loop iterations consumed (≤ k_max).
    pub iterations: usize,
    /// Planner's internal latency estimate before planning (seconds).
    pub est_before: f64,
    /// Planner's internal latency estimate after planning (seconds).
    pub est_after: f64,
}

impl PlanOutcome {
    /// New fetches planned onto `rank`.
    pub fn fetch_slots(&self, rank: usize) -> usize {
        self.fetches[rank].len()
    }
    /// Largest per-rank fetch count (the eq. 6 numerator).
    pub fn max_fetch_slots(&self) -> usize {
        self.fetches.iter().map(|f| f.len()).max().unwrap_or(0)
    }
    /// Total new fetches across ranks.
    pub fn total_fetches(&self) -> usize {
        self.fetches.iter().map(|f| f.len()).sum()
    }
}

/// Planner internal per-rank latency estimate: compute time plus a
/// (non-deduplicated, conservative) traffic term — the eq. 8 objective.
pub fn rank_latencies(a: &Assignment, model: &MoeModel, hw: &HardwareProfile) -> Vec<f64> {
    LatencyState::from_assignment(a, model, hw).latencies()
}

/// Eq. 8 objective with inter-node rail congestion added (topology-aware
/// planning over a multi-node [`Fabric`]).
pub fn rank_latencies_on(
    a: &Assignment,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
) -> Vec<f64> {
    LatencyState::from_assignment_on(a, model, hw, fabric).latencies()
}

/// Per-node inter-node traffic terms of the eq. 8 objective: every
/// cross-node flow loads its source node's egress rails and its target
/// node's ingress rails, which all ranks of the node share.
#[derive(Debug, Clone)]
struct RailCongestion {
    node_of: Vec<usize>,
    n_in: Vec<f64>,
    n_out: Vec<f64>,
    /// Effective aggregate rail bandwidth per node per direction.
    bw: f64,
}

/// Incrementally-maintained per-rank latency terms of the eq. 8
/// objective. A flow shift touches O(1) ranks, so the greedy loop pays
/// O(shift) instead of the full O(E·ep²) recompute per candidate.
#[derive(Debug, Clone)]
pub struct LatencyState {
    ep: usize,
    token_bytes: f64,
    bw: f64,
    comp: Vec<f64>,
    v_in: Vec<f64>,
    v_out: Vec<f64>,
    /// tokens_on(e, r), indexed `e * ep + r`.
    tok: Vec<f64>,
    /// Per-node rail congestion terms (None = flat / topology-blind:
    /// the scalar objective, unchanged from the pre-fabric planner).
    rail: Option<RailCongestion>,
}

impl LatencyState {
    /// Build the state under the scalar (topology-blind) objective.
    pub fn from_assignment(a: &Assignment, model: &MoeModel, hw: &HardwareProfile) -> LatencyState {
        Self::from_assignment_on(a, model, hw, None)
    }

    /// Build the state, optionally carrying per-link (rail) congestion
    /// for a multi-node fabric. A flat fabric degenerates to the scalar
    /// objective.
    pub fn from_assignment_on(
        a: &Assignment,
        model: &MoeModel,
        hw: &HardwareProfile,
        fabric: Option<&Fabric>,
    ) -> LatencyState {
        let ep = a.ep;
        let tb = model.token_bytes();
        let rail = match fabric {
            Some(f) if !f.is_flat() => Some(RailCongestion {
                node_of: (0..ep).map(|r| f.node_of(r)).collect(),
                n_in: vec![0.0; f.n_nodes()],
                n_out: vec![0.0; f.n_nodes()],
                bw: f.rail_bw() * f.inter.efficiency,
            }),
            _ => None,
        };
        let mut st = LatencyState {
            ep,
            token_bytes: tb,
            bw: hw.effective_alltoall_bw(),
            comp: vec![0.0; ep],
            v_in: vec![0.0; ep],
            v_out: vec![0.0; ep],
            tok: vec![0.0; a.n_experts * ep],
            rail,
        };
        for e in 0..a.n_experts {
            for rt in 0..ep {
                let n = a.tokens_on(e, rt);
                if n > 0.0 {
                    st.tok[e * ep + rt] = n;
                    st.comp[rt] += expert_compute_time(n, model, hw);
                    st.v_in[rt] += a.remote_tokens_on(e, rt) * tb;
                }
            }
            for rs in 0..ep {
                for rt in 0..ep {
                    if rs != rt {
                        let x = a.get(e, rs, rt);
                        if x > 0.0 {
                            st.v_out[rs] += x * tb;
                            if let Some(rc) = st.rail.as_mut() {
                                if rc.node_of[rs] != rc.node_of[rt] {
                                    rc.n_out[rc.node_of[rs]] += x * tb;
                                    rc.n_in[rc.node_of[rt]] += x * tb;
                                }
                            }
                        }
                    }
                }
            }
        }
        st
    }

    /// Estimated latency of rank `r` under the current flows.
    #[inline]
    pub fn latency(&self, r: usize) -> f64 {
        let port = self.v_in[r].max(self.v_out[r]) / self.bw;
        let traffic = match &self.rail {
            None => port,
            Some(rc) => {
                let n = rc.node_of[r];
                port.max(rc.n_in[n].max(rc.n_out[n]) / rc.bw)
            }
        };
        self.comp[r] + traffic
    }

    /// Per-rank latency estimates.
    pub fn latencies(&self) -> Vec<f64> {
        (0..self.ep).map(|r| self.latency(r)).collect()
    }

    /// Allocation-free [`Self::latencies`]: writes into a caller buffer.
    pub fn latencies_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend((0..self.ep).map(|r| self.latency(r)));
    }

    /// Bottleneck-rank latency estimate (the greedy objective).
    pub fn max_latency(&self) -> f64 {
        (0..self.ep).map(|r| self.latency(r)).fold(0.0, f64::max)
    }

    /// Tokens of expert `e` currently executing on rank `r`.
    pub fn tokens_on(&self, e: usize, r: usize) -> f64 {
        self.tok[e * self.ep + r]
    }

    /// Mirror `Assignment::shift(e, rs, from, to, x)` on the latency
    /// terms. `x` must be the amount actually moved.
    pub fn apply_shift(
        &mut self,
        e: usize,
        rs: usize,
        from: usize,
        to: usize,
        x: f64,
        model: &MoeModel,
        hw: &HardwareProfile,
    ) {
        if x <= 0.0 || from == to {
            return;
        }
        let i_from = e * self.ep + from;
        let i_to = e * self.ep + to;
        self.comp[from] +=
            expert_compute_time(self.tok[i_from] - x, model, hw) - expert_compute_time(self.tok[i_from], model, hw);
        self.comp[to] +=
            expert_compute_time(self.tok[i_to] + x, model, hw) - expert_compute_time(self.tok[i_to], model, hw);
        self.tok[i_from] -= x;
        self.tok[i_to] += x;
        let tb = self.token_bytes;
        if rs != from {
            self.v_in[from] -= x * tb;
        }
        if rs != to {
            self.v_in[to] += x * tb;
        }
        let was_remote = rs != from;
        let is_remote = rs != to;
        if was_remote != is_remote {
            let sign = if is_remote { 1.0 } else { -1.0 };
            self.v_out[rs] += sign * x * tb;
        }
        if let Some(rc) = self.rail.as_mut() {
            // the rs→from flow shrinks, the rs→to flow grows; each loads
            // the rails only when it crosses nodes
            if rc.node_of[rs] != rc.node_of[from] {
                rc.n_out[rc.node_of[rs]] -= x * tb;
                rc.n_in[rc.node_of[from]] -= x * tb;
            }
            if rc.node_of[rs] != rc.node_of[to] {
                rc.n_out[rc.node_of[rs]] += x * tb;
                rc.n_in[rc.node_of[to]] += x * tb;
            }
        }
    }

    /// [`Self::apply_shift`] that journals the raw pre-shift values of
    /// every touched cell into `log`, so [`Self::undo_shifts`] can later
    /// restore the state *bit-exactly* (reversing the arithmetic would
    /// not: `(v ± x) ∓ x ≠ v` in f64). A shift that is a no-op
    /// (`x ≤ 0` or `from == to`) logs nothing.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_shift_logged(
        &mut self,
        e: usize,
        rs: usize,
        from: usize,
        to: usize,
        x: f64,
        model: &MoeModel,
        hw: &HardwareProfile,
        log: &mut Vec<StateUndo>,
    ) {
        if x <= 0.0 || from == to {
            return;
        }
        let i_from = e * self.ep + from;
        let i_to = e * self.ep + to;
        log.push(StateUndo {
            rs,
            from,
            to,
            i_from,
            i_to,
            tok_from: self.tok[i_from],
            tok_to: self.tok[i_to],
            comp_from: self.comp[from],
            comp_to: self.comp[to],
            v_in_from: self.v_in[from],
            v_in_to: self.v_in[to],
            v_out_rs: self.v_out[rs],
            rail: self.rail.as_ref().map(|rc| {
                (
                    rc.n_out[rc.node_of[rs]],
                    rc.n_in[rc.node_of[from]],
                    rc.n_in[rc.node_of[to]],
                )
            }),
        });
        self.apply_shift(e, rs, from, to, x, model, hw);
    }

    /// Pop and revert journal entries down to `mark` (LIFO), restoring
    /// the exact pre-shift bits recorded by [`Self::apply_shift_logged`].
    /// All snapshots in one entry predate that entry's mutation, so
    /// restore order within an entry is alias-safe even when two rail
    /// terms share a node.
    pub fn undo_shifts(&mut self, log: &mut Vec<StateUndo>, mark: usize) {
        while log.len() > mark {
            let u = log.pop().expect("journal underflow");
            self.tok[u.i_from] = u.tok_from;
            self.tok[u.i_to] = u.tok_to;
            self.comp[u.from] = u.comp_from;
            self.comp[u.to] = u.comp_to;
            self.v_in[u.from] = u.v_in_from;
            self.v_in[u.to] = u.v_in_to;
            self.v_out[u.rs] = u.v_out_rs;
            if let Some((out_rs, in_from, in_to)) = u.rail {
                let rc = self.rail.as_mut().expect("rail journal without rail state");
                rc.n_in[rc.node_of[u.to]] = in_to;
                rc.n_in[rc.node_of[u.from]] = in_from;
                rc.n_out[rc.node_of[u.rs]] = out_rs;
            }
        }
    }
}

/// Raw-value journal entry recorded by [`LatencyState::apply_shift_logged`]
/// and reverted by [`LatencyState::undo_shifts`]. Opaque to callers.
#[derive(Debug, Clone, Copy)]
pub struct StateUndo {
    rs: usize,
    from: usize,
    to: usize,
    i_from: usize,
    i_to: usize,
    tok_from: f64,
    tok_to: f64,
    comp_from: f64,
    comp_to: f64,
    v_in_from: f64,
    v_in_to: f64,
    v_out_rs: f64,
    /// Pre-shift (n_out[node(rs)], n_in[node(from)], n_in[node(to)]).
    rail: Option<(f64, f64, f64)>,
}

/// Reusable planner working memory (ISSUE 6): every `Vec` the greedy
/// loop, water-filling, and polish passes need is held here and reset
/// (`clear`, never freed) between calls, so a long-lived caller — e.g.
/// the PROBE balancer planning every layer of every step — performs no
/// steady-state heap allocation inside the planner.
/// `PlanScratch::default()` starts empty; buffers grow to the
/// high-water mark of the workload and stay there.
///
/// Routing a plan through a scratch does not change its output:
/// [`plan_fabric_with`] is bit-identical to [`plan_fabric`] (which is
/// now a thin wrapper constructing a fresh scratch).
#[derive(Debug, Clone, Default)]
pub struct PlanScratch {
    lat: Vec<f64>,
    lat2: Vec<f64>,
    wf_lat: Vec<f64>,
    src_heap: BinaryHeap<(LatKey, Reverse<usize>)>,
    dst_heap: BinaryHeap<Reverse<(LatKey, usize)>>,
    dst_sorted: Vec<usize>,
    invalid: Vec<(usize, usize)>,
    totals: Vec<f64>,
    hosts: Vec<usize>,
    node_win: Vec<f64>,
    node_out_slots: Vec<usize>,
    node_in_slots: Vec<usize>,
    cands: Vec<(usize, usize, f64)>,
    dead: Vec<(usize, usize)>,
    a_log: Vec<ShiftUndo>,
    st_log: Vec<StateUndo>,
}

/// Marginal seconds per additional token of expert `e` at load `n`.
fn marginal_time(n: f64, model: &MoeModel, hw: &HardwareProfile) -> f64 {
    let eff = crate::perfmodel::gemm_efficiency(n.max(1.0), hw);
    model.per_token_flops() / (eff * hw.peak_flops)
}

/// Evict replicas whose predicted load fell below the per-expert mean:
/// the slot is reclaimed for free (overwrite), and only hot experts keep
/// their zero-cost resident copies.
fn drop_cold_replicas(
    placement: &mut Placement,
    counts_by_source: &[Vec<f64>],
    totals: &mut Vec<f64>,
    hosts: &mut Vec<usize>,
) {
    totals.clear();
    totals.extend(counts_by_source.iter().map(|v| v.iter().sum::<f64>()));
    let n = totals.len().max(1) as f64;
    let mean = totals.iter().sum::<f64>() / n;
    for e in 0..placement.n_experts {
        if totals[e] < mean {
            hosts.clear();
            hosts.extend(placement.hosts_iter(e).skip(1)); // replicas only
            for &r in hosts.iter() {
                let _ = placement.remove_replica(e, r);
            }
        }
    }
}

/// Algorithm 1 with delta planning on a flat (single-node) fabric and
/// an uncapped slot budget — the pre-governor planner, preserved for
/// single-node call sites. Memory-governed callers use [`plan_fabric`]
/// with the live per-rank headroom instead.
pub fn plan(
    counts_by_source: &[Vec<f64>],
    resident: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    windows: &[f64],
    cfg: &ProbeConfig,
) -> PlanOutcome {
    plan_fabric(
        counts_by_source,
        resident,
        model,
        hw,
        &Fabric::flat(resident.ep, hw),
        windows,
        &vec![usize::MAX; resident.ep],
        cfg,
    )
}

/// Evict replicas beyond each rank's live slot cap (the memory
/// governor shrank the headroom since they were fetched): coldest
/// predicted load first — eviction is a free overwrite, so the only
/// cost is losing the replica's balance contribution.
fn enforce_slot_caps(
    placement: &mut Placement,
    counts_by_source: &[Vec<f64>],
    caps: &[usize],
    totals: &mut Vec<f64>,
) {
    totals.clear();
    totals.extend(counts_by_source.iter().map(|v| v.iter().sum::<f64>()));
    for r in 0..placement.ep {
        let cap = caps.get(r).copied().unwrap_or(usize::MAX);
        while placement.slots_used(r) > cap {
            // coldest replica on r; `<=` keeps the last minimal expert,
            // matching the previous `Iterator::min_by` tie-breaking
            let mut victim: Option<(usize, f64)> = None;
            for e in 0..placement.n_experts {
                if placement.home_rank(e) == r || !placement.hosts(e, r) {
                    continue;
                }
                let t = totals.get(e).copied().unwrap_or(0.0);
                if victim.map_or(true, |(_, tv)| t <= tv) {
                    victim = Some((e, t));
                }
            }
            match victim {
                Some((e, _)) => {
                    let _ = placement.remove_replica(e, r);
                }
                None => break,
            }
        }
    }
}

/// Source rank a replica of `e` is fetched from onto `dst`. Topology-
/// aware planning prefers a host inside `dst`'s node (NVSwitch-speed
/// copy); blind planning (and flat fabrics) always reads from the first
/// host — the home shard.
fn pick_source(
    placement: &Placement,
    e: usize,
    dst: usize,
    fabric: &Fabric,
    aware: bool,
) -> usize {
    if !aware {
        return placement.home_rank(e);
    }
    placement
        .hosts_iter(e) // home first
        .find(|&r| fabric.same_node(r, dst))
        .unwrap_or_else(|| placement.home_rank(e))
}

/// Algorithm 1 with delta planning over an interconnect [`Fabric`].
/// `counts_by_source[e][rs]` are the *predicted* per-expert per-source
/// token counts for the target layer; `resident` is the placement
/// currently in HBM for that layer (replicas fetched by earlier plans);
/// `windows[r]` is the per-rank hiding window (seconds of overlappable
/// compute) budgeting NEW fetches only; `slot_caps[r]` is the memory
/// governor's live replica headroom
/// ([`crate::placement::memory::MemoryManager::replica_caps`]) — the
/// plan never holds more than `slot_caps[r]` replicas on rank `r`, so
/// replication is bounded by actual free HBM rather than the fixed
/// `max_redundant` alone, shrinking automatically as KV pressure rises
/// (resident replicas above a shrunken cap are evicted coldest-first).
///
/// Topology-aware mode (`cfg.topology_aware`, multi-node fabrics):
/// replica fetches prefer intra-node sources, the single per-rank window
/// check becomes per-link feasibility (destination port, per-flow rail
/// line rate, shared node rail aggregates), and the greedy objective's
/// [`LatencyState`] carries per-node rail congestion. Topology-blind
/// mode keeps the scalar checks — the ablation `probe bench fabric`
/// compares against.
pub fn plan_fabric(
    counts_by_source: &[Vec<f64>],
    resident: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: &Fabric,
    windows: &[f64],
    slot_caps: &[usize],
    cfg: &ProbeConfig,
) -> PlanOutcome {
    plan_fabric_with(
        &mut PlanScratch::default(),
        counts_by_source,
        resident,
        model,
        hw,
        fabric,
        windows,
        slot_caps,
        cfg,
    )
}

/// [`plan_fabric`] with caller-held working memory: identical output,
/// but every internal buffer (latency snapshots, candidate orders,
/// water-fill journals, eviction scratch) lives in `scratch` and is
/// reused across calls instead of reallocated. Speculative water-fill
/// candidates mutate the live assignment/state in place under a
/// raw-value journal and are rolled back bit-exactly on rejection —
/// replacing the per-iteration O(E·ep²) clone of the old greedy loop.
#[allow(clippy::too_many_arguments)]
pub fn plan_fabric_with(
    scratch: &mut PlanScratch,
    counts_by_source: &[Vec<f64>],
    resident: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: &Fabric,
    windows: &[f64],
    slot_caps: &[usize],
    cfg: &ProbeConfig,
) -> PlanOutcome {
    let ep = resident.ep;
    assert_eq!(windows.len(), ep);
    assert_eq!(slot_caps.len(), ep);
    let aware = cfg.topology_aware && !fabric.is_flat();
    let fab_opt = if aware { Some(fabric) } else { None };
    let mut placement = resident.clone();
    if cfg.delta_plan {
        drop_cold_replicas(
            &mut placement,
            counts_by_source,
            &mut scratch.totals,
            &mut scratch.hosts,
        );
    } else {
        placement.clear_replicas();
    }
    // live memory headroom: evict what no longer fits before planning
    enforce_slot_caps(&mut placement, counts_by_source, slot_caps, &mut scratch.totals);
    let retained_replicas = placement.total_replicas();

    let mut a = Assignment::locality_first_from_counts(counts_by_source, &placement);
    let mut st = LatencyState::from_assignment_on(&a, model, hw, fab_opt);
    let est_before = st.max_latency();

    // Zero-cost reuse: water-fill over the retained replicas before any
    // new fetch is considered (no transfer, no slot, no budget charge).
    if retained_replicas > 0 {
        a = polish_assignment_with(scratch, a, &placement, model, hw, fab_opt, 16);
        st = LatencyState::from_assignment_on(&a, model, hw, fab_opt);
    }

    // min hiding window per node: shared rail budgets must fit the
    // tightest window among the ranks the rails serve
    scratch.node_win.clear();
    for n in 0..fabric.n_nodes() {
        let mut w = f64::INFINITY;
        for r in 0..ep {
            if fabric.node_of(r) == n {
                w = w.min(windows[r]);
            }
        }
        scratch.node_win.push(w);
    }

    let mut fetches: Vec<Vec<usize>> = vec![Vec::new(); ep];
    let mut fetch_flows: Vec<Flow> = Vec::new();
    scratch.node_out_slots.clear();
    scratch.node_out_slots.resize(fabric.n_nodes(), 0);
    scratch.node_in_slots.clear();
    scratch.node_in_slots.resize(fabric.n_nodes(), 0);
    scratch.invalid.clear();
    let mut iterations = 0usize;
    let eps = est_before * 1e-3;
    let expert_bytes = model.expert_param_bytes();

    loop {
        if iterations >= cfg.k_max {
            break;
        }
        iterations += 1;

        // select bottleneck/helper pair, skipping invalidated pairs
        st.latencies_into(&mut scratch.lat);
        let Some((r_src, r_dst)) = select_pair(
            &scratch.lat,
            &placement,
            slot_caps,
            &scratch.invalid,
            &mut scratch.src_heap,
            &mut scratch.dst_heap,
            &mut scratch.dst_sorted,
        ) else {
            break;
        };

        // hottest expert on r_src with a movable remote pool
        let Some(e_star) = select_heavy_expert(&a, &placement, r_src, r_dst) else {
            scratch.invalid.push((r_src, r_dst));
            continue;
        };
        let fetch_src = pick_source(&placement, e_star, r_dst, fabric, aware);

        // dual-side budget check (eq. 6 vs hiding window): the fetch on
        // r_dst and the slot overwrite (evict) both bound the same slot
        // count; cyclic slot reuse makes |Δ_out| = |Δ_in| per rank. Only
        // NEW fetches are charged — retained replicas already transferred.
        if cfg.enforce_window {
            let slots_after = fetches[r_dst].len() + 1;
            if transfer_time(slots_after, model, hw) > windows[r_dst] {
                scratch.invalid.push((r_src, r_dst));
                continue;
            }
            if aware && !fabric.same_node(fetch_src, r_dst) {
                // per-link feasibility for the cross-node path: the
                // flow's own rail line rate + rendezvous latency, then
                // the shared node egress/ingress rail aggregates
                let t_flow = fabric.transfer_time_flow(&Flow {
                    src: fetch_src,
                    dst: r_dst,
                    bytes: expert_bytes,
                });
                if t_flow > windows[r_dst] {
                    scratch.invalid.push((r_src, r_dst));
                    continue;
                }
                let ns = fabric.node_of(fetch_src);
                let nd = fabric.node_of(r_dst);
                let t_rail =
                    |slots: usize| slots as f64 * expert_bytes / fabric.rail_bw();
                if t_rail(scratch.node_out_slots[ns] + 1) > scratch.node_win[ns]
                    || t_rail(scratch.node_in_slots[nd] + 1) > scratch.node_win[nd]
                {
                    scratch.invalid.push((r_src, r_dst));
                    continue;
                }
            }
        }
        if placement.slots_free(r_dst) == 0 || placement.slots_used(r_dst) >= slot_caps[r_dst] {
            scratch.invalid.push((r_src, r_dst));
            continue;
        }

        // tentative replica + water-filling rebalance, journaled in
        // place: rejection rolls the exact pre-candidate bits back
        let before_max = st.max_latency();
        scratch.a_log.clear();
        scratch.st_log.clear();
        let moved = water_fill(
            &mut a,
            &mut st,
            e_star,
            r_src,
            r_dst,
            model,
            hw,
            cfg.water_filling,
            &mut scratch.wf_lat,
            &mut scratch.a_log,
            &mut scratch.st_log,
        );
        if moved <= 0.0 {
            a.undo_shifts(&mut scratch.a_log, 0);
            st.undo_shifts(&mut scratch.st_log, 0);
            scratch.invalid.push((r_src, r_dst));
            continue;
        }
        let gain = before_max - st.max_latency();
        if gain <= eps {
            a.undo_shifts(&mut scratch.a_log, 0);
            st.undo_shifts(&mut scratch.st_log, 0);
            break; // converged (Algorithm 1 line 12)
        }
        placement
            .add_replica(e_star, r_dst)
            .expect("slot availability pre-checked");
        fetches[r_dst].push(e_star);
        fetch_flows.push(Flow {
            src: fetch_src,
            dst: r_dst,
            bytes: expert_bytes,
        });
        if !fabric.same_node(fetch_src, r_dst) {
            scratch.node_out_slots[fabric.node_of(fetch_src)] += 1;
            scratch.node_in_slots[fabric.node_of(r_dst)] += 1;
        }
    }

    let est_after = st.max_latency();
    PlanOutcome {
        placement,
        assignment: a,
        fetches,
        fetch_flows,
        retained_replicas,
        iterations,
        est_before,
        est_after,
    }
}

/// Total-order key over finite rank latencies for the candidate heaps.
/// Ordering is `partial_cmp` exactly as the stable sorts it replaces
/// used (panics on NaN — latencies are finite), so ±0.0 compare equal
/// and the index tiebreaker decides, preserving selection order
/// bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
struct LatKey(f64);

impl Eq for LatKey {}

impl PartialOrd for LatKey {
    fn partial_cmp(&self, other: &LatKey) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for LatKey {
    fn cmp(&self, other: &LatKey) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN rank latency")
    }
}

/// Pick (argmax, argmin) latency ranks avoiding invalidated pairs; the
/// destination must have a free replica slot within its live memory
/// cap.
///
/// Binary-heap candidate selection: instead of fully sorting both rank
/// orders every greedy iteration, sources pop from a max-heap and
/// destinations materialize lazily from a min-heap into `dst_sorted`,
/// so the common case touches one source and a short ascending prefix
/// of destinations. Ties break toward the smaller index on both sides,
/// matching the stable sorts this replaces — selection is bit-identical
/// (`select_pair_sorted` in the test module pins parity).
fn select_pair(
    lat: &[f64],
    placement: &Placement,
    slot_caps: &[usize],
    invalid: &[(usize, usize)],
    src_heap: &mut BinaryHeap<(LatKey, Reverse<usize>)>,
    dst_heap: &mut BinaryHeap<Reverse<(LatKey, usize)>>,
    dst_sorted: &mut Vec<usize>,
) -> Option<(usize, usize)> {
    src_heap.clear();
    src_heap.extend(lat.iter().enumerate().map(|(i, &l)| (LatKey(l), Reverse(i))));
    dst_heap.clear();
    dst_heap.extend(lat.iter().enumerate().map(|(i, &l)| Reverse((LatKey(l), i))));
    dst_sorted.clear();
    while let Some((LatKey(ls), Reverse(s))) = src_heap.pop() {
        let mut di = 0usize;
        loop {
            let d = match dst_sorted.get(di) {
                Some(&d) => d,
                None => match dst_heap.pop() {
                    Some(Reverse((_, d))) => {
                        dst_sorted.push(d);
                        d
                    }
                    None => break,
                },
            };
            di += 1;
            // destinations arrive in ascending latency: once the gap
            // filter fails it fails for every remaining one (d == s is
            // subsumed — lat[s] >= lat[s])
            if lat[d] >= ls {
                break;
            }
            if placement.slots_free(d) == 0
                || placement.slots_used(d) >= slot_caps.get(d).copied().unwrap_or(usize::MAX)
            {
                continue;
            }
            if !invalid.contains(&(s, d)) {
                return Some((s, d));
            }
        }
    }
    None
}

/// Hottest expert executed on `r_src` that is not yet hosted on `r_dst`
/// and has remote tokens available to shed.
fn select_heavy_expert(
    a: &Assignment,
    placement: &Placement,
    r_src: usize,
    r_dst: usize,
) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for e in 0..a.n_experts {
        if !placement.hosts(e, r_src) || placement.hosts(e, r_dst) {
            continue;
        }
        let load = a.tokens_on(e, r_src);
        let movable = a.remote_tokens_on(e, r_src);
        if movable <= 0.0 {
            continue;
        }
        if best.map_or(true, |(_, l)| load > l) {
            best = Some((e, load));
        }
    }
    best.map(|(e, _)| e)
}

/// Locality-aware water-filling (paper §4.3): tokens generated on `r_src`
/// stay pinned; remote tokens are redirected to `r_dst` until `r_src`
/// reaches the cluster average (or the pool empties). The naive ablation
/// variant moves half the pool unconditionally. Updates the incremental
/// latency state alongside the assignment; every mutation is journaled
/// into `a_log`/`st_log` so the caller can roll the candidate back
/// bit-exactly if it does not pay off.
#[allow(clippy::too_many_arguments)]
fn water_fill(
    a: &mut Assignment,
    st: &mut LatencyState,
    e_star: usize,
    r_src: usize,
    r_dst: usize,
    model: &MoeModel,
    hw: &HardwareProfile,
    water_filling: bool,
    lat_buf: &mut Vec<f64>,
    a_log: &mut Vec<ShiftUndo>,
    st_log: &mut Vec<StateUndo>,
) -> f64 {
    let ep = a.ep;
    let pool: f64 = a.remote_tokens_on(e_star, r_src);
    if pool <= 0.0 {
        return 0.0;
    }
    let target_tokens = if water_filling {
        st.latencies_into(lat_buf);
        let avg = lat_buf.iter().sum::<f64>() / ep as f64;
        let excess = (lat_buf[r_src] - avg).max(0.0);
        let marginal = marginal_time(a.tokens_on(e_star, r_src), model, hw);
        if marginal <= 0.0 {
            return 0.0;
        }
        (excess / marginal).min(pool)
    } else {
        pool / 2.0
    };
    if target_tokens <= 0.0 {
        return 0.0;
    }
    // proportional drain across remote sources
    let mut remaining = target_tokens;
    for rs in 0..ep {
        if rs == r_src {
            continue; // locality-first: pinned
        }
        let have = a.get(e_star, rs, r_src);
        if have <= 0.0 {
            continue;
        }
        let share = (have / pool * target_tokens).min(remaining);
        let moved = a.shift_logged(e_star, rs, r_src, r_dst, share, a_log);
        st.apply_shift_logged(e_star, rs, r_src, r_dst, moved, model, hw, st_log);
        remaining -= moved;
        if remaining <= 1e-9 {
            break;
        }
    }
    target_tokens - remaining
}

/// Re-derive the token assignment for the *actual* routing once the
/// placement is fixed (the router knows the true top-k at dispatch time;
/// only placement had to be decided ahead). Greedy water-filling across
/// the existing replicas; no budget checks (no new transfers happen).
pub fn rebalance_existing(
    counts_by_source: &[Vec<f64>],
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    iters: usize,
) -> Assignment {
    rebalance_existing_on(counts_by_source, placement, model, hw, None, iters)
}

/// [`rebalance_existing`] with optional rail congestion in the objective
/// (topology-aware dispatch rebalancing on multi-node fabrics).
pub fn rebalance_existing_on(
    counts_by_source: &[Vec<f64>],
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
    iters: usize,
) -> Assignment {
    rebalance_existing_with(
        &mut PlanScratch::default(),
        counts_by_source,
        placement,
        model,
        hw,
        fabric,
        iters,
    )
}

/// [`rebalance_existing_on`] with caller-held working memory (see
/// [`PlanScratch`]); the per-step dispatch rebalance in the balancers
/// routes through this to stay allocation-free at steady state.
pub fn rebalance_existing_with(
    scratch: &mut PlanScratch,
    counts_by_source: &[Vec<f64>],
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
    iters: usize,
) -> Assignment {
    let a = Assignment::locality_first_from_counts(counts_by_source, placement);
    polish_assignment_with(scratch, a, placement, model, hw, fabric, iters)
}

/// Iteratively improve an assignment over a FIXED placement: move remote
/// tokens of experts hosted on the bottleneck rank toward their less-
/// loaded replicas (pairwise equalization). Candidates that fail to
/// improve are skipped, not fatal.
pub fn polish_assignment(
    a: Assignment,
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    iters: usize,
) -> Assignment {
    polish_assignment_on(a, placement, model, hw, None, iters)
}

/// [`polish_assignment`] under the fabric-aware objective: with a
/// multi-node fabric the bottleneck metric includes rail congestion, so
/// the polish also sheds cross-node traffic when the rails bind.
pub fn polish_assignment_on(
    a: Assignment,
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
    iters: usize,
) -> Assignment {
    polish_assignment_with(&mut PlanScratch::default(), a, placement, model, hw, fabric, iters)
}

/// [`polish_assignment_on`] with caller-held working memory. Candidate
/// moves are applied to the live assignment under a raw-value journal
/// and evaluated against an incrementally-maintained [`LatencyState`]
/// (instead of cloning the assignment and recomputing the full
/// O(E·ep²) objective per candidate); rejected candidates are rolled
/// back bit-exactly. The incremental objective can differ from a full
/// recompute by f64 rounding (~1e-15), which only matters on exact
/// ties between candidates — the accept threshold keeps its 1e-12
/// margin.
pub fn polish_assignment_with(
    scratch: &mut PlanScratch,
    mut a: Assignment,
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
    iters: usize,
) -> Assignment {
    let mut st = LatencyState::from_assignment_on(&a, model, hw, fabric);
    st.latencies_into(&mut scratch.lat);
    scratch.dead.clear(); // (expert, dst) that failed
    scratch.a_log.clear();
    scratch.st_log.clear();
    for _ in 0..iters {
        let r_src = argmax(&scratch.lat);
        // candidate moves off the bottleneck, best (most movable) first
        scratch.cands.clear();
        for e in 0..a.n_experts {
            if !placement.hosts(e, r_src) {
                continue;
            }
            let movable = a.remote_tokens_on(e, r_src);
            if movable <= 0.0 {
                continue;
            }
            for rt in placement.hosts_iter(e) {
                if rt == r_src
                    || scratch.lat[rt] >= scratch.lat[r_src]
                    || scratch.dead.contains(&(e, rt))
                {
                    continue;
                }
                scratch.cands.push((e, rt, movable.min(a.tokens_on(e, r_src))));
            }
        }
        if scratch.cands.is_empty() {
            break;
        }
        scratch.cands.sort_by(|x, y| y.2.partial_cmp(&x.2).unwrap());
        let mut progressed = false;
        for ci in 0..scratch.cands.len().min(4) {
            let (e_star, r_dst, _) = scratch.cands[ci];
            // pairwise equalization: close half the latency gap
            let marginal = marginal_time(a.tokens_on(e_star, r_src), model, hw);
            if marginal <= 0.0 {
                continue;
            }
            let want = ((scratch.lat[r_src] - scratch.lat[r_dst]) * 0.5 / marginal).max(0.0);
            let pool = a.remote_tokens_on(e_star, r_src);
            let target = want.min(pool);
            if target <= 0.0 {
                scratch.dead.push((e_star, r_dst));
                continue;
            }
            let mut remaining = target;
            for rs in 0..a.ep {
                if rs == r_src {
                    continue;
                }
                let have = a.get(e_star, rs, r_src);
                if have <= 0.0 {
                    continue;
                }
                let moved = a.shift_logged(
                    e_star,
                    rs,
                    r_src,
                    r_dst,
                    (have / pool * target).min(remaining),
                    &mut scratch.a_log,
                );
                st.apply_shift_logged(
                    e_star, rs, r_src, r_dst, moved, model, hw, &mut scratch.st_log,
                );
                remaining -= moved;
                if remaining <= 1e-9 {
                    break;
                }
            }
            st.latencies_into(&mut scratch.lat2);
            if scratch.lat2[argmax(&scratch.lat2)] < scratch.lat[r_src] - 1e-12 {
                std::mem::swap(&mut scratch.lat, &mut scratch.lat2);
                scratch.a_log.clear();
                scratch.st_log.clear();
                progressed = true;
                break;
            }
            a.undo_shifts(&mut scratch.a_log, 0);
            st.undo_shifts(&mut scratch.st_log, 0);
            scratch.dead.push((e_star, r_dst));
        }
        if !progressed {
            break;
        }
    }
    a
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// One plan request snapshotted for the background control pipeline:
/// everything [`plan_fabric_with`] reads, captured at observe time.
/// Because the planner is a pure function of these inputs (scratch
/// contents never change its output — pinned by
/// `scratch_planner_matches_allocating_planner_on_drift`), a worker
/// replaying the snapshot produces bits identical to an inline call at
/// the same point in the step.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    /// Predicted `counts_by_source[e][rs]` for the target layer.
    pub counts: Vec<Vec<f64>>,
    /// Resident placement of the target layer — the delta-plan base.
    pub resident: Placement,
    /// Per-rank hiding windows budgeting NEW fetches.
    pub windows: Vec<f64>,
    /// Live per-rank replica-slot caps from the memory governor.
    pub slot_caps: Vec<usize>,
}

/// Deterministic background control plane (ISSUE 10): a small worker
/// pool computing [`plan_fabric_with`] off the critical path.
///
/// The handoff discipline mirrors `util::parallel::ordered_map`: every
/// submission gets a monotone ticket, tasks round-robin across workers
/// by `ticket % threads` (no shared work queue, so the task→worker
/// assignment is deterministic), and the caller seals results by ticket
/// — out-of-order arrivals park in a small stash until their seal.
/// Since the planner is pure in its request, a pipelined run is
/// bit-identical to the synchronous one; only wall-clock changes.
///
/// [`ControlPipeline::seal`] returns `(plan, plan_wall, block_wall)`:
/// the worker-side seconds the plan took and the caller-side seconds
/// spent blocked waiting for it. `plan_wall − block_wall` is the
/// control time the pipeline actually hid behind the caller's own
/// work.
pub struct ControlPipeline {
    task_tx: Vec<mpsc::Sender<(u64, PlanRequest)>>,
    result_rx: mpsc::Receiver<(u64, PlanOutcome, f64)>,
    workers: Vec<thread::JoinHandle<()>>,
    next_ticket: u64,
    /// Results that arrived ahead of their seal; bounded by the
    /// in-flight plan count (≤ 1 per balancer layer slot).
    stash: Vec<(u64, PlanOutcome, f64)>,
}

impl std::fmt::Debug for ControlPipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ControlPipeline")
            .field("workers", &self.workers.len())
            .field("next_ticket", &self.next_ticket)
            .field("stashed", &self.stash.len())
            .finish()
    }
}

impl ControlPipeline {
    /// Spawn `threads.max(1)` plan workers, each owning a clone of the
    /// immutable planning context and a private [`PlanScratch`].
    pub fn new(
        threads: usize,
        model: MoeModel,
        hw: HardwareProfile,
        fabric: Fabric,
        cfg: ProbeConfig,
    ) -> ControlPipeline {
        let threads = threads.max(1);
        let (result_tx, result_rx) = mpsc::channel();
        let mut task_tx = Vec::with_capacity(threads);
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let (tx, rx) = mpsc::channel::<(u64, PlanRequest)>();
            task_tx.push(tx);
            let results = result_tx.clone();
            let (model, hw, fabric, cfg) = (model.clone(), hw.clone(), fabric.clone(), cfg.clone());
            workers.push(thread::spawn(move || {
                let mut scratch = PlanScratch::default();
                while let Ok((ticket, req)) = rx.recv() {
                    let t0 = Instant::now();
                    let out = plan_fabric_with(
                        &mut scratch,
                        &req.counts,
                        &req.resident,
                        &model,
                        &hw,
                        &fabric,
                        &req.windows,
                        &req.slot_caps,
                        &cfg,
                    );
                    let plan_wall = t0.elapsed().as_secs_f64();
                    if results.send((ticket, out, plan_wall)).is_err() {
                        break; // pipeline dropped mid-flight
                    }
                }
            }));
        }
        ControlPipeline {
            task_tx,
            result_rx,
            workers,
            next_ticket: 0,
            stash: Vec::new(),
        }
    }

    /// Enqueue a plan; returns the ticket that seals it.
    pub fn submit(&mut self, req: PlanRequest) -> u64 {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let w = (ticket % self.task_tx.len() as u64) as usize;
        self.task_tx[w]
            .send((ticket, req))
            .expect("control worker died");
        ticket
    }

    /// Block until `ticket`'s plan is ready and return
    /// `(plan, plan_wall_secs, block_wall_secs)`.
    pub fn seal(&mut self, ticket: u64) -> (PlanOutcome, f64, f64) {
        if let Some(i) = self.stash.iter().position(|(t, _, _)| *t == ticket) {
            let (_, out, plan_wall) = self.stash.swap_remove(i);
            return (out, plan_wall, 0.0);
        }
        let t0 = Instant::now();
        loop {
            let (t, out, plan_wall) = self.result_rx.recv().expect("control worker died");
            if t == ticket {
                return (out, plan_wall, t0.elapsed().as_secs_f64());
            }
            self.stash.push((t, out, plan_wall));
        }
    }
}

impl Drop for ControlPipeline {
    fn drop(&mut self) {
        self.task_tx.clear(); // close task channels: workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::RoutingModel;
    use crate::util::stats::imbalance_ratio;

    fn setup(n_tokens: usize, seed: u64) -> (Vec<Vec<f64>>, Placement, MoeModel, HardwareProfile) {
        let model = MoeModel::gpt_oss_120b();
        let mut rm = RoutingModel::calibrated(1, model.n_experts, model.top_k, 3, seed);
        let routing = rm.route_step(&vec![0u16; n_tokens]).layers.remove(0);
        let counts: Vec<Vec<f64>> = routing
            .expert_counts_by_source(8)
            .into_iter()
            .map(|v| v.into_iter().map(|c| c as f64).collect())
            .collect();
        let placement = Placement::sharded(8, model.n_experts, 3);
        (counts, placement, model, HardwareProfile::hopper_141())
    }

    fn wide_windows() -> Vec<f64> {
        vec![1.0; 8] // effectively unconstrained
    }

    #[test]
    fn plan_reduces_bottleneck() {
        let (counts, base, model, hw) = setup(6144, 3);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        assert!(
            out.est_after < out.est_before * 0.95,
            "no improvement: {} -> {}",
            out.est_before,
            out.est_after
        );
        assert!(out.iterations <= cfg.k_max);
        out.placement.validate().unwrap();
    }

    #[test]
    fn plan_conserves_tokens() {
        let (counts, base, model, hw) = setup(2048, 5);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        for e in 0..model.n_experts {
            let want: f64 = counts[e].iter().sum();
            let got = out.assignment.expert_total(e);
            assert!((want - got).abs() < 1e-6, "expert {e}: {want} vs {got}");
        }
    }

    #[test]
    fn plan_respects_slot_budget() {
        let (counts, base, model, hw) = setup(4096, 7);
        let mut cfg = ProbeConfig::default();
        cfg.max_redundant = 1;
        let mut base1 = Placement::sharded(base.ep, base.n_experts, 1);
        base1.clear_replicas();
        let out = plan(&counts, &base1, &model, &hw, &wide_windows(), &cfg);
        for r in 0..8 {
            assert!(out.placement.slots_used(r) <= 1);
        }
    }

    #[test]
    fn tight_window_blocks_replication() {
        let (counts, base, model, hw) = setup(4096, 9);
        let cfg = ProbeConfig::default();
        // window shorter than one expert transfer → no replicas possible
        let w = transfer_time(1, &model, &hw) * 0.5;
        let out = plan(&counts, &base, &model, &hw, &vec![w; 8], &cfg);
        assert_eq!(out.placement.total_replicas(), 0);
        assert_eq!(out.est_after, out.est_before);
    }

    #[test]
    fn window_disabled_ablation_replicates_anyway() {
        let (counts, base, model, hw) = setup(4096, 9);
        let mut cfg = ProbeConfig::default();
        cfg.enforce_window = false;
        let w = transfer_time(1, &model, &hw) * 0.5;
        let out = plan(&counts, &base, &model, &hw, &vec![w; 8], &cfg);
        assert!(out.placement.total_replicas() > 0);
    }

    #[test]
    fn locality_pinned_tokens_never_move() {
        let (counts, base, model, hw) = setup(3072, 11);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        // tokens originating on an expert's home rank stay there
        for e in 0..model.n_experts {
            let home = base.home_rank(e);
            let pinned = counts[e][home];
            assert!(
                (out.assignment.get(e, home, home) - pinned).abs() < 1e-9,
                "expert {e}: pinned tokens moved"
            );
        }
    }

    #[test]
    fn planned_ir_improves() {
        let (counts, base, model, hw) = setup(6144, 13);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        let loads_of = |a: &Assignment| -> Vec<f64> {
            (0..8)
                .map(|r| (0..model.n_experts).map(|e| a.tokens_on(e, r)).sum())
                .collect()
        };
        let before = Assignment::locality_first_from_counts(&counts, &base);
        let ir_b = imbalance_ratio(&loads_of(&before));
        let ir_a = imbalance_ratio(&loads_of(&out.assignment));
        assert!(ir_a < ir_b, "IR {ir_b} -> {ir_a}");
    }

    #[test]
    fn iteration_budget_respected() {
        let (counts, base, model, hw) = setup(8192, 15);
        let mut cfg = ProbeConfig::default();
        cfg.k_max = 2;
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        assert!(out.iterations <= 2);
        assert!(out.placement.total_replicas() <= 2);
    }

    #[test]
    fn rebalance_existing_respects_placement() {
        let (counts, base, model, hw) = setup(4096, 17);
        let cfg = ProbeConfig::default();
        let planned = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        // re-derive with slightly different (actual) counts
        let mut actual = counts.clone();
        actual[0][0] += 8.0;
        actual[1][0] = (actual[1][0] - 8.0).max(0.0);
        let a = rebalance_existing(&actual, &planned.placement, &model, &hw, 32);
        let counts_u32: Vec<u32> = actual
            .iter()
            .map(|v| v.iter().sum::<f64>() as u32)
            .collect();
        a.validate(&counts_u32, &planned.placement).unwrap();
    }

    #[test]
    fn water_filling_beats_naive_split() {
        let (counts, base, model, hw) = setup(6144, 19);
        let mut cfg = ProbeConfig::default();
        let wf = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        cfg.water_filling = false;
        let naive = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        assert!(
            wf.est_after <= naive.est_after * 1.05,
            "water-filling {} vs naive {}",
            wf.est_after,
            naive.est_after
        );
    }

    #[test]
    fn incremental_state_matches_full_recompute() {
        let (counts, base, model, hw) = setup(4096, 21);
        let mut placement = base.clone();
        placement.add_replica(0, 7).unwrap();
        placement.add_replica(1, 6).unwrap();
        let mut a = Assignment::locality_first_from_counts(&counts, &placement);
        let mut st = LatencyState::from_assignment(&a, &model, &hw);
        // a handful of arbitrary legal shifts, mirrored on the state
        for (e, rs, from, to, x) in [
            (0usize, 2usize, 0usize, 7usize, 5.0f64),
            (0, 3, 0, 7, 11.0),
            (1, 5, 0, 6, 7.0),
            (0, 2, 7, 0, 2.0),
        ] {
            let moved = a.shift(e, rs, from, to, x);
            st.apply_shift(e, rs, from, to, moved, &model, &hw);
        }
        let full = LatencyState::from_assignment(&a, &model, &hw).latencies();
        let inc = st.latencies();
        for (r, (f, i)) in full.iter().zip(&inc).enumerate() {
            assert!((f - i).abs() < 1e-9, "rank {r}: full {f} vs incremental {i}");
        }
    }

    #[test]
    fn delta_plan_reuses_resident_replicas() {
        let (counts, base, model, hw) = setup(6144, 23);
        let cfg = ProbeConfig::default();
        assert!(cfg.delta_plan);
        // first plan from the empty base: everything is a fresh fetch
        let first = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        let first_fetches = first.total_fetches();
        assert!(first_fetches > 0, "first plan fetched nothing");
        assert_eq!(first.retained_replicas, 0);
        // re-plan the SAME predicted counts against the resident
        // placement: the hot replicas are already there — zero fetches
        let second = plan(&counts, &first.placement, &model, &hw, &wide_windows(), &cfg);
        assert!(second.retained_replicas > 0);
        assert!(
            second.total_fetches() < first_fetches,
            "delta plan refetched: {} vs {}",
            second.total_fetches(),
            first_fetches
        );
        // and the balance quality does not regress
        assert!(second.est_after <= first.est_after * 1.05);
        second.placement.validate().unwrap();
    }

    #[test]
    fn fetch_sources_prefer_intra_node() {
        let fabric = Fabric::multi_node_ratio(4, 2, &HardwareProfile::hopper_141(), 0.25, 2);
        let mut p = Placement::sharded(4, 8, 3);
        // expert 0: home rank 0 (node 0), resident replica on rank 2 (node 1)
        p.add_replica(0, 2).unwrap();
        assert_eq!(pick_source(&p, 0, 3, &fabric, true), 2, "same-node copy");
        assert_eq!(pick_source(&p, 0, 3, &fabric, false), 0, "blind reads home");
        assert_eq!(pick_source(&p, 0, 1, &fabric, true), 0, "home is already intra");
        // expert 5 (home rank 2, node 1) fetched into node 0: no intra
        // host exists, fall back to the home shard
        assert_eq!(pick_source(&p, 5, 0, &fabric, true), 2);
    }

    #[test]
    fn rail_infeasible_fetches_stay_intra_node_when_aware() {
        let model = MoeModel::gpt_oss_120b();
        let hw = HardwareProfile::hopper_141();
        let mut rm = RoutingModel::calibrated(1, model.n_experts, model.top_k, 3, 27);
        let routing = rm.route_step(&vec![0u16; 8192]).layers.remove(0);
        let counts: Vec<Vec<f64>> = routing
            .expert_counts_by_source(16)
            .into_iter()
            .map(|v| v.into_iter().map(|c| c as f64).collect())
            .collect();
        let base = Placement::sharded(16, model.n_experts, 3);
        // rails at 1/16 of NVSwitch: a cross-node expert copy takes 16×
        // the window; intra copies fit two slots
        let fabric = Fabric::multi_node_ratio(16, 2, &hw, 1.0 / 16.0, 2);
        let windows = vec![transfer_time(2, &model, &hw); 16];
        let mut cfg = ProbeConfig::default();
        let caps = vec![usize::MAX; 16];
        cfg.topology_aware = true;
        let aware = plan_fabric(&counts, &base, &model, &hw, &fabric, &windows, &caps, &cfg);
        cfg.topology_aware = false;
        let blind = plan_fabric(&counts, &base, &model, &hw, &fabric, &windows, &caps, &cfg);
        assert!(blind.total_fetches() > 0, "blind planner fetched nothing");
        let cross = |o: &PlanOutcome| {
            o.fetch_flows
                .iter()
                .filter(|f| !fabric.same_node(f.src, f.dst))
                .count()
        };
        assert_eq!(cross(&aware), 0, "aware planner scheduled a rail-infeasible fetch");
        assert!(cross(&blind) >= cross(&aware));
        assert_eq!(aware.fetch_flows.len(), aware.total_fetches());
    }

    #[test]
    fn incremental_rail_state_matches_full_recompute() {
        let (counts, base, model, hw) = setup(4096, 29);
        let fabric = Fabric::multi_node_ratio(8, 2, &hw, 0.125, 2);
        let mut placement = base.clone();
        placement.add_replica(0, 7).unwrap();
        placement.add_replica(1, 6).unwrap();
        let mut a = Assignment::locality_first_from_counts(&counts, &placement);
        let mut st = LatencyState::from_assignment_on(&a, &model, &hw, Some(&fabric));
        // shifts that cross and re-cross the node boundary (ranks 0–3
        // node 0, ranks 4–7 node 1)
        for (e, rs, from, to, x) in [
            (0usize, 2usize, 0usize, 7usize, 5.0f64),
            (0, 3, 0, 7, 11.0),
            (1, 5, 0, 6, 7.0),
            (0, 2, 7, 0, 2.0),
        ] {
            let moved = a.shift(e, rs, from, to, x);
            st.apply_shift(e, rs, from, to, moved, &model, &hw);
        }
        let full =
            LatencyState::from_assignment_on(&a, &model, &hw, Some(&fabric)).latencies();
        let inc = st.latencies();
        for (r, (f, i)) in full.iter().zip(&inc).enumerate() {
            assert!((f - i).abs() < 1e-9, "rank {r}: full {f} vs incremental {i}");
        }
    }

    #[test]
    fn slot_caps_bound_replication_per_rank() {
        let (counts, base, model, hw) = setup(6144, 31);
        let cfg = ProbeConfig::default();
        let fabric = Fabric::flat(8, &hw);
        // ragged caps: rank r may hold at most r % 3 replicas
        let caps: Vec<usize> = (0..8).map(|r| r % 3).collect();
        let out = plan_fabric(
            &counts, &base, &model, &hw, &fabric, &wide_windows(), &caps, &cfg,
        );
        for r in 0..8 {
            assert!(
                out.placement.slots_used(r) <= caps[r],
                "rank {r}: {} replicas over cap {}",
                out.placement.slots_used(r),
                caps[r]
            );
        }
        out.placement.validate().unwrap();
        // an all-zero cap vector forbids replication entirely even with
        // wide windows (the KV-pressure endgame)
        let none = plan_fabric(
            &counts, &base, &model, &hw, &fabric, &wide_windows(), &vec![0; 8], &cfg,
        );
        assert_eq!(none.placement.total_replicas(), 0);
        assert_eq!(none.est_after, none.est_before);
    }

    #[test]
    fn shrinking_caps_evict_resident_replicas_monotonically() {
        // replicate under generous headroom, then re-plan the SAME
        // forecast against progressively tighter caps with no fetch
        // budget left (k_max = 0): the resident replica count must
        // shrink monotonically to zero and never exceed any cap — the
        // ISSUE 5 co-balancing tension at planner level
        let (counts, base, model, hw) = setup(6144, 33);
        let mut cfg = ProbeConfig::default();
        assert!(cfg.delta_plan);
        cfg.k_max = 64;
        let fabric = Fabric::flat(8, &hw);
        let generous = plan_fabric(
            &counts,
            &base,
            &model,
            &hw,
            &fabric,
            &wide_windows(),
            &vec![3; 8],
            &cfg,
        );
        assert!(
            generous.placement.total_replicas() > 0,
            "planner never replicated under generous caps"
        );
        cfg.k_max = 0; // pressure phase: evictions only
        let mut resident = generous.placement;
        let mut last_total = resident.total_replicas();
        for cap in (0..3usize).rev() {
            let out = plan_fabric(
                &counts,
                &resident,
                &model,
                &hw,
                &fabric,
                &wide_windows(),
                &vec![cap; 8],
                &cfg,
            );
            let total = out.placement.total_replicas();
            for r in 0..8 {
                assert!(out.placement.slots_used(r) <= cap, "cap {cap} rank {r}");
            }
            assert!(
                total <= last_total,
                "replicas grew as headroom shrank: {last_total} -> {total} at cap {cap}"
            );
            out.placement.validate().unwrap();
            last_total = total;
            resident = out.placement;
        }
        assert_eq!(last_total, 0, "cap 0 must evict every replica");
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // one long-lived scratch reused across heterogeneous plans must
        // give the same bits as a fresh scratch per call (ISSUE 6)
        let cfg = ProbeConfig::default();
        let mut scratch = PlanScratch::default();
        let mut resident: Option<Placement> = None;
        for seed in [3u64, 5, 9, 23] {
            let (counts, base, model, hw) = setup(4096, seed);
            let from = resident.as_ref().unwrap_or(&base).clone();
            let fabric = Fabric::flat(8, &hw);
            let caps = vec![usize::MAX; 8];
            let fresh = plan_fabric(
                &counts, &from, &model, &hw, &fabric, &wide_windows(), &caps, &cfg,
            );
            let reused = plan_fabric_with(
                &mut scratch,
                &counts,
                &from,
                &model,
                &hw,
                &fabric,
                &wide_windows(),
                &caps,
                &cfg,
            );
            assert_eq!(fresh.est_before.to_bits(), reused.est_before.to_bits());
            assert_eq!(fresh.est_after.to_bits(), reused.est_after.to_bits());
            assert_eq!(fresh.iterations, reused.iterations);
            assert_eq!(fresh.fetches, reused.fetches);
            assert_eq!(fresh.retained_replicas, reused.retained_replicas);
            for e in 0..model.n_experts {
                for r in 0..8 {
                    assert_eq!(
                        fresh.assignment.tokens_on(e, r).to_bits(),
                        reused.assignment.tokens_on(e, r).to_bits(),
                        "expert {e} rank {r} diverged (seed {seed})"
                    );
                }
            }
            resident = Some(reused.placement);
        }
    }

    #[test]
    fn logged_state_undo_restores_bit_exact() {
        let (counts, base, model, hw) = setup(4096, 37);
        let fabric = Fabric::multi_node_ratio(8, 2, &hw, 0.125, 2);
        let mut placement = base.clone();
        placement.add_replica(0, 7).unwrap();
        placement.add_replica(1, 6).unwrap();
        let mut a = Assignment::locality_first_from_counts(&counts, &placement);
        let mut st = LatencyState::from_assignment_on(&a, &model, &hw, Some(&fabric));
        let lat_before = st.latencies();
        let mut a_log = Vec::new();
        let mut st_log = Vec::new();
        // shifts crossing the node boundary both ways, then a no-op
        for (e, rs, from, to, x) in [
            (0usize, 2usize, 0usize, 7usize, 5.0f64),
            (0, 3, 0, 7, 11.0),
            (1, 5, 0, 6, 7.0),
            (0, 2, 7, 0, 2.0),
            (0, 2, 0, 0, 3.0), // from == to: state logs nothing
        ] {
            let moved = a.shift_logged(e, rs, from, to, x, &mut a_log);
            st.apply_shift_logged(e, rs, from, to, moved, &model, &hw, &mut st_log);
        }
        assert!(st_log.len() <= a_log.len());
        a.undo_shifts(&mut a_log, 0);
        st.undo_shifts(&mut st_log, 0);
        assert!(a_log.is_empty() && st_log.is_empty());
        let lat_after = st.latencies();
        for (r, (b, c)) in lat_before.iter().zip(&lat_after).enumerate() {
            assert_eq!(b.to_bits(), c.to_bits(), "rank {r} not restored exactly");
        }
        // and the assignment matches a fresh locality-first build
        let fresh = Assignment::locality_first_from_counts(&counts, &placement);
        for e in 0..model.n_experts {
            for rs in 0..8 {
                for rt in 0..8 {
                    assert_eq!(
                        a.get(e, rs, rt).to_bits(),
                        fresh.get(e, rs, rt).to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn clear_mode_never_retains() {
        let (counts, base, model, hw) = setup(4096, 25);
        let mut cfg = ProbeConfig::default();
        cfg.delta_plan = false;
        let first = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        let second = plan(&counts, &first.placement, &model, &hw, &wide_windows(), &cfg);
        assert_eq!(second.retained_replicas, 0);
        // clear-every-layer refetches its full replica set
        assert_eq!(second.total_fetches(), second.placement.total_replicas());
    }

    /// The full-sort `select_pair` this PR's heap version replaced,
    /// kept verbatim as the parity reference.
    fn select_pair_sorted(
        lat: &[f64],
        placement: &Placement,
        slot_caps: &[usize],
        invalid: &[(usize, usize)],
    ) -> Option<(usize, usize)> {
        let ep = lat.len();
        let mut src_order: Vec<usize> = (0..ep).collect();
        src_order.sort_by(|&x, &y| lat[y].partial_cmp(&lat[x]).unwrap());
        let mut dst_order: Vec<usize> = (0..ep).collect();
        dst_order.sort_by(|&x, &y| lat[x].partial_cmp(&lat[y]).unwrap());
        for &s in &src_order {
            for &d in &dst_order {
                if d == s || lat[d] >= lat[s] {
                    continue;
                }
                if placement.slots_free(d) == 0
                    || placement.slots_used(d) >= slot_caps.get(d).copied().unwrap_or(usize::MAX)
                {
                    continue;
                }
                if !invalid.contains(&(s, d)) {
                    return Some((s, d));
                }
            }
        }
        None
    }

    #[test]
    fn heap_select_pair_matches_sorted_reference() {
        let mut rng = 0x243f_6a88_85a3_08d3u64;
        let mut next = move || {
            rng ^= rng << 13;
            rng ^= rng >> 7;
            rng ^= rng << 17;
            rng
        };
        let mut src_heap = BinaryHeap::new();
        let mut dst_heap = BinaryHeap::new();
        let mut dst_sorted = Vec::new();
        for trial in 0..400 {
            let ep = [2usize, 4, 8, 13][(next() % 4) as usize];
            // quantized latencies force frequent ties to exercise the
            // index tiebreaker against the stable sorts
            let lat: Vec<f64> = (0..ep).map(|_| (next() % 7) as f64 * 0.125).collect();
            let mut placement = Placement::sharded(ep, ep * 2, 3);
            for _ in 0..(next() % 12) {
                let e = (next() as usize) % (ep * 2);
                let r = (next() as usize) % ep;
                let _ = placement.add_replica(e, r);
            }
            let slot_caps: Vec<usize> = (0..ep)
                .map(|_| {
                    if next() % 3 == 0 {
                        usize::MAX
                    } else {
                        (next() % 5) as usize
                    }
                })
                .collect();
            let invalid: Vec<(usize, usize)> = (0..(next() % 6))
                .map(|_| ((next() as usize) % ep, (next() as usize) % ep))
                .collect();
            let want = select_pair_sorted(&lat, &placement, &slot_caps, &invalid);
            let got = select_pair(
                &lat,
                &placement,
                &slot_caps,
                &invalid,
                &mut src_heap,
                &mut dst_heap,
                &mut dst_sorted,
            );
            assert_eq!(got, want, "trial {trial}: lat={lat:?} caps={slot_caps:?}");
        }
    }

    #[test]
    fn control_pipeline_matches_inline_planner_bit_for_bit() {
        let model = MoeModel::gpt_oss_120b();
        let hw = HardwareProfile::hopper_141();
        let fabric = Fabric::flat(8, &hw);
        let cfg = ProbeConfig::default();
        let mut pipe =
            ControlPipeline::new(2, model.clone(), hw.clone(), fabric.clone(), cfg.clone());
        let mut scratch = PlanScratch::default();
        let slot_caps = vec![usize::MAX; 8];
        let mut resident = Placement::sharded(8, model.n_experts, 3);
        let mut tickets = Vec::new();
        let mut inline = Vec::new();
        for step in 0..4u64 {
            let (counts, _, _, _) = setup(4096, 40 + step);
            let req = PlanRequest {
                counts,
                resident: resident.clone(),
                windows: wide_windows(),
                slot_caps: slot_caps.clone(),
            };
            tickets.push(pipe.submit(req.clone()));
            let out = plan_fabric_with(
                &mut scratch,
                &req.counts,
                &req.resident,
                &model,
                &hw,
                &fabric,
                &req.windows,
                &req.slot_caps,
                &cfg,
            );
            // drift the resident base between plans like the balancer does
            resident = out.placement.clone();
            inline.push(out);
        }
        // seal deliberately out of ticket order: later seals must come
        // from the stash, earlier ones from the live channel
        for &i in &[2usize, 0, 3, 1] {
            let (out, plan_wall, block_wall) = pipe.seal(tickets[i]);
            assert_eq!(
                format!("{out:?}"),
                format!("{:?}", inline[i]),
                "pipelined plan {i} diverged from inline"
            );
            assert!(plan_wall > 0.0 && block_wall >= 0.0);
        }
    }
}
