//! Hardware-Aware Balance Planning (paper §4.3, Algorithm 1).
//!
//! Greedy rebalancing: repeatedly pair the bottleneck rank `r_src` with
//! the least-loaded rank `r_dst`, replicate `r_src`'s hottest movable
//! expert onto `r_dst` (gated by the dual-side transfer budget so the
//! prefetch hides inside the per-rank window), and redistribute that
//! expert's *remote* tokens with locality-first water-filling. Stops at
//! convergence (gain ≤ ε) or the iteration cap `k_max`.
//!
//! Two refinements over the literal Algorithm 1 (ISSUE 2):
//! * **Delta planning** (`cfg.delta_plan`): instead of clearing all
//!   replicas and re-planning from the static base every layer, the plan
//!   starts from the *resident* placement (what the previous plan for
//!   this layer left in HBM), evicts only replicas whose predicted load
//!   went cold (eviction is a free overwrite), reuses the still-hot ones
//!   at zero transfer cost, and reports only the *new* fetches in
//!   [`PlanOutcome::fetches`]. On drifting workloads the per-layer fetch
//!   volume drops to the hotspot diff.
//! * **Incremental latency state** ([`LatencyState`]): the greedy loop
//!   updates per-rank compute/traffic terms as flows shift instead of
//!   recomputing the full O(E·ep²) [`rank_latencies`] per iteration.

use crate::config::ProbeConfig;
use crate::fabric::{Fabric, Flow};
use crate::model::MoeModel;
use crate::perfmodel::{expert_compute_time, transfer_time, Assignment};
use crate::placement::Placement;
use crate::topology::HardwareProfile;

/// Result of one planning invocation (one layer, one step).
#[derive(Debug, Clone)]
pub struct PlanOutcome {
    /// Planned placement for the target layer.
    pub placement: Placement,
    /// Token assignment over the predicted counts.
    pub assignment: Assignment,
    /// Experts NEWLY fetched per rank this plan (|Δ_r^in| minus reuse).
    pub fetches: Vec<Vec<usize>>,
    /// Routed source→destination transfer flows behind `fetches` (one
    /// per fetched expert; source chosen topology-aware when enabled).
    pub fetch_flows: Vec<Flow>,
    /// Resident replicas reused at zero transfer cost (delta planning).
    pub retained_replicas: usize,
    /// Loop iterations consumed (≤ k_max).
    pub iterations: usize,
    /// Planner's internal latency estimate before planning (seconds).
    pub est_before: f64,
    /// Planner's internal latency estimate after planning (seconds).
    pub est_after: f64,
}

impl PlanOutcome {
    /// New fetches planned onto `rank`.
    pub fn fetch_slots(&self, rank: usize) -> usize {
        self.fetches[rank].len()
    }
    /// Largest per-rank fetch count (the eq. 6 numerator).
    pub fn max_fetch_slots(&self) -> usize {
        self.fetches.iter().map(|f| f.len()).max().unwrap_or(0)
    }
    /// Total new fetches across ranks.
    pub fn total_fetches(&self) -> usize {
        self.fetches.iter().map(|f| f.len()).sum()
    }
}

/// Planner internal per-rank latency estimate: compute time plus a
/// (non-deduplicated, conservative) traffic term — the eq. 8 objective.
pub fn rank_latencies(a: &Assignment, model: &MoeModel, hw: &HardwareProfile) -> Vec<f64> {
    LatencyState::from_assignment(a, model, hw).latencies()
}

/// Eq. 8 objective with inter-node rail congestion added (topology-aware
/// planning over a multi-node [`Fabric`]).
pub fn rank_latencies_on(
    a: &Assignment,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
) -> Vec<f64> {
    LatencyState::from_assignment_on(a, model, hw, fabric).latencies()
}

/// Per-node inter-node traffic terms of the eq. 8 objective: every
/// cross-node flow loads its source node's egress rails and its target
/// node's ingress rails, which all ranks of the node share.
#[derive(Debug, Clone)]
struct RailCongestion {
    node_of: Vec<usize>,
    n_in: Vec<f64>,
    n_out: Vec<f64>,
    /// Effective aggregate rail bandwidth per node per direction.
    bw: f64,
}

/// Incrementally-maintained per-rank latency terms of the eq. 8
/// objective. A flow shift touches O(1) ranks, so the greedy loop pays
/// O(shift) instead of the full O(E·ep²) recompute per candidate.
#[derive(Debug, Clone)]
pub struct LatencyState {
    ep: usize,
    token_bytes: f64,
    bw: f64,
    comp: Vec<f64>,
    v_in: Vec<f64>,
    v_out: Vec<f64>,
    /// tokens_on(e, r), indexed `e * ep + r`.
    tok: Vec<f64>,
    /// Per-node rail congestion terms (None = flat / topology-blind:
    /// the scalar objective, unchanged from the pre-fabric planner).
    rail: Option<RailCongestion>,
}

impl LatencyState {
    /// Build the state under the scalar (topology-blind) objective.
    pub fn from_assignment(a: &Assignment, model: &MoeModel, hw: &HardwareProfile) -> LatencyState {
        Self::from_assignment_on(a, model, hw, None)
    }

    /// Build the state, optionally carrying per-link (rail) congestion
    /// for a multi-node fabric. A flat fabric degenerates to the scalar
    /// objective.
    pub fn from_assignment_on(
        a: &Assignment,
        model: &MoeModel,
        hw: &HardwareProfile,
        fabric: Option<&Fabric>,
    ) -> LatencyState {
        let ep = a.ep;
        let tb = model.token_bytes();
        let rail = match fabric {
            Some(f) if !f.is_flat() => Some(RailCongestion {
                node_of: (0..ep).map(|r| f.node_of(r)).collect(),
                n_in: vec![0.0; f.n_nodes()],
                n_out: vec![0.0; f.n_nodes()],
                bw: f.rail_bw() * f.inter.efficiency,
            }),
            _ => None,
        };
        let mut st = LatencyState {
            ep,
            token_bytes: tb,
            bw: hw.effective_alltoall_bw(),
            comp: vec![0.0; ep],
            v_in: vec![0.0; ep],
            v_out: vec![0.0; ep],
            tok: vec![0.0; a.n_experts * ep],
            rail,
        };
        for e in 0..a.n_experts {
            for rt in 0..ep {
                let n = a.tokens_on(e, rt);
                if n > 0.0 {
                    st.tok[e * ep + rt] = n;
                    st.comp[rt] += expert_compute_time(n, model, hw);
                    st.v_in[rt] += a.remote_tokens_on(e, rt) * tb;
                }
            }
            for rs in 0..ep {
                for rt in 0..ep {
                    if rs != rt {
                        let x = a.get(e, rs, rt);
                        if x > 0.0 {
                            st.v_out[rs] += x * tb;
                            if let Some(rc) = st.rail.as_mut() {
                                if rc.node_of[rs] != rc.node_of[rt] {
                                    rc.n_out[rc.node_of[rs]] += x * tb;
                                    rc.n_in[rc.node_of[rt]] += x * tb;
                                }
                            }
                        }
                    }
                }
            }
        }
        st
    }

    /// Estimated latency of rank `r` under the current flows.
    #[inline]
    pub fn latency(&self, r: usize) -> f64 {
        let port = self.v_in[r].max(self.v_out[r]) / self.bw;
        let traffic = match &self.rail {
            None => port,
            Some(rc) => {
                let n = rc.node_of[r];
                port.max(rc.n_in[n].max(rc.n_out[n]) / rc.bw)
            }
        };
        self.comp[r] + traffic
    }

    /// Per-rank latency estimates.
    pub fn latencies(&self) -> Vec<f64> {
        (0..self.ep).map(|r| self.latency(r)).collect()
    }

    /// Bottleneck-rank latency estimate (the greedy objective).
    pub fn max_latency(&self) -> f64 {
        (0..self.ep).map(|r| self.latency(r)).fold(0.0, f64::max)
    }

    /// Tokens of expert `e` currently executing on rank `r`.
    pub fn tokens_on(&self, e: usize, r: usize) -> f64 {
        self.tok[e * self.ep + r]
    }

    /// Mirror `Assignment::shift(e, rs, from, to, x)` on the latency
    /// terms. `x` must be the amount actually moved.
    pub fn apply_shift(
        &mut self,
        e: usize,
        rs: usize,
        from: usize,
        to: usize,
        x: f64,
        model: &MoeModel,
        hw: &HardwareProfile,
    ) {
        if x <= 0.0 || from == to {
            return;
        }
        let i_from = e * self.ep + from;
        let i_to = e * self.ep + to;
        self.comp[from] +=
            expert_compute_time(self.tok[i_from] - x, model, hw) - expert_compute_time(self.tok[i_from], model, hw);
        self.comp[to] +=
            expert_compute_time(self.tok[i_to] + x, model, hw) - expert_compute_time(self.tok[i_to], model, hw);
        self.tok[i_from] -= x;
        self.tok[i_to] += x;
        let tb = self.token_bytes;
        if rs != from {
            self.v_in[from] -= x * tb;
        }
        if rs != to {
            self.v_in[to] += x * tb;
        }
        let was_remote = rs != from;
        let is_remote = rs != to;
        if was_remote != is_remote {
            let sign = if is_remote { 1.0 } else { -1.0 };
            self.v_out[rs] += sign * x * tb;
        }
        if let Some(rc) = self.rail.as_mut() {
            // the rs→from flow shrinks, the rs→to flow grows; each loads
            // the rails only when it crosses nodes
            if rc.node_of[rs] != rc.node_of[from] {
                rc.n_out[rc.node_of[rs]] -= x * tb;
                rc.n_in[rc.node_of[from]] -= x * tb;
            }
            if rc.node_of[rs] != rc.node_of[to] {
                rc.n_out[rc.node_of[rs]] += x * tb;
                rc.n_in[rc.node_of[to]] += x * tb;
            }
        }
    }
}

/// Marginal seconds per additional token of expert `e` at load `n`.
fn marginal_time(n: f64, model: &MoeModel, hw: &HardwareProfile) -> f64 {
    let eff = crate::perfmodel::gemm_efficiency(n.max(1.0), hw);
    model.per_token_flops() / (eff * hw.peak_flops)
}

/// Evict replicas whose predicted load fell below the per-expert mean:
/// the slot is reclaimed for free (overwrite), and only hot experts keep
/// their zero-cost resident copies.
fn drop_cold_replicas(placement: &mut Placement, counts_by_source: &[Vec<f64>]) {
    let totals: Vec<f64> = counts_by_source.iter().map(|v| v.iter().sum()).collect();
    let n = totals.len().max(1) as f64;
    let mean = totals.iter().sum::<f64>() / n;
    for e in 0..placement.n_experts {
        if totals[e] < mean {
            for r in placement.ranks_hosting(e).into_iter().skip(1) {
                let _ = placement.remove_replica(e, r);
            }
        }
    }
}

/// Algorithm 1 with delta planning on a flat (single-node) fabric and
/// an uncapped slot budget — the pre-governor planner, preserved for
/// single-node call sites. Memory-governed callers use [`plan_fabric`]
/// with the live per-rank headroom instead.
pub fn plan(
    counts_by_source: &[Vec<f64>],
    resident: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    windows: &[f64],
    cfg: &ProbeConfig,
) -> PlanOutcome {
    plan_fabric(
        counts_by_source,
        resident,
        model,
        hw,
        &Fabric::flat(resident.ep, hw),
        windows,
        &vec![usize::MAX; resident.ep],
        cfg,
    )
}

/// Evict replicas beyond each rank's live slot cap (the memory
/// governor shrank the headroom since they were fetched): coldest
/// predicted load first — eviction is a free overwrite, so the only
/// cost is losing the replica's balance contribution.
fn enforce_slot_caps(placement: &mut Placement, counts_by_source: &[Vec<f64>], caps: &[usize]) {
    let totals: Vec<f64> = counts_by_source.iter().map(|v| v.iter().sum()).collect();
    for r in 0..placement.ep {
        let cap = caps.get(r).copied().unwrap_or(usize::MAX);
        while placement.slots_used(r) > cap {
            let victim = placement
                .replica_experts(r)
                .into_iter()
                .min_by(|&a, &b| {
                    let ta = totals.get(a).copied().unwrap_or(0.0);
                    let tb = totals.get(b).copied().unwrap_or(0.0);
                    ta.partial_cmp(&tb).unwrap_or(std::cmp::Ordering::Equal)
                });
            match victim {
                Some(e) => {
                    let _ = placement.remove_replica(e, r);
                }
                None => break,
            }
        }
    }
}

/// Source rank a replica of `e` is fetched from onto `dst`. Topology-
/// aware planning prefers a host inside `dst`'s node (NVSwitch-speed
/// copy); blind planning (and flat fabrics) always reads from the first
/// host — the home shard.
fn pick_source(
    placement: &Placement,
    e: usize,
    dst: usize,
    fabric: &Fabric,
    aware: bool,
) -> usize {
    let hosts = placement.ranks_hosting(e); // home first
    if !aware {
        return hosts[0];
    }
    hosts
        .iter()
        .copied()
        .find(|&r| fabric.same_node(r, dst))
        .unwrap_or(hosts[0])
}

/// Algorithm 1 with delta planning over an interconnect [`Fabric`].
/// `counts_by_source[e][rs]` are the *predicted* per-expert per-source
/// token counts for the target layer; `resident` is the placement
/// currently in HBM for that layer (replicas fetched by earlier plans);
/// `windows[r]` is the per-rank hiding window (seconds of overlappable
/// compute) budgeting NEW fetches only; `slot_caps[r]` is the memory
/// governor's live replica headroom
/// ([`crate::placement::memory::MemoryManager::replica_caps`]) — the
/// plan never holds more than `slot_caps[r]` replicas on rank `r`, so
/// replication is bounded by actual free HBM rather than the fixed
/// `max_redundant` alone, shrinking automatically as KV pressure rises
/// (resident replicas above a shrunken cap are evicted coldest-first).
///
/// Topology-aware mode (`cfg.topology_aware`, multi-node fabrics):
/// replica fetches prefer intra-node sources, the single per-rank window
/// check becomes per-link feasibility (destination port, per-flow rail
/// line rate, shared node rail aggregates), and the greedy objective's
/// [`LatencyState`] carries per-node rail congestion. Topology-blind
/// mode keeps the scalar checks — the ablation `probe bench fabric`
/// compares against.
pub fn plan_fabric(
    counts_by_source: &[Vec<f64>],
    resident: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: &Fabric,
    windows: &[f64],
    slot_caps: &[usize],
    cfg: &ProbeConfig,
) -> PlanOutcome {
    let ep = resident.ep;
    assert_eq!(windows.len(), ep);
    assert_eq!(slot_caps.len(), ep);
    let aware = cfg.topology_aware && !fabric.is_flat();
    let fab_opt = if aware { Some(fabric) } else { None };
    let mut placement = resident.clone();
    if cfg.delta_plan {
        drop_cold_replicas(&mut placement, counts_by_source);
    } else {
        placement.clear_replicas();
    }
    // live memory headroom: evict what no longer fits before planning
    enforce_slot_caps(&mut placement, counts_by_source, slot_caps);
    let retained_replicas = placement.total_replicas();

    let mut a = Assignment::locality_first_from_counts(counts_by_source, &placement);
    let mut st = LatencyState::from_assignment_on(&a, model, hw, fab_opt);
    let est_before = st.max_latency();

    // Zero-cost reuse: water-fill over the retained replicas before any
    // new fetch is considered (no transfer, no slot, no budget charge).
    if retained_replicas > 0 {
        a = polish_assignment_on(a, &placement, model, hw, fab_opt, 16);
        st = LatencyState::from_assignment_on(&a, model, hw, fab_opt);
    }

    // min hiding window per node: shared rail budgets must fit the
    // tightest window among the ranks the rails serve
    let node_win: Vec<f64> = (0..fabric.n_nodes())
        .map(|n| {
            (0..ep)
                .filter(|&r| fabric.node_of(r) == n)
                .map(|r| windows[r])
                .fold(f64::INFINITY, f64::min)
        })
        .collect();

    let mut fetches: Vec<Vec<usize>> = vec![Vec::new(); ep];
    let mut fetch_flows: Vec<Flow> = Vec::new();
    let mut node_out_slots = vec![0usize; fabric.n_nodes()];
    let mut node_in_slots = vec![0usize; fabric.n_nodes()];
    let mut invalid: Vec<(usize, usize)> = Vec::new();
    let mut iterations = 0usize;
    let eps = est_before * 1e-3;
    let expert_bytes = model.expert_param_bytes();

    loop {
        if iterations >= cfg.k_max {
            break;
        }
        iterations += 1;

        // select bottleneck/helper pair, skipping invalidated pairs
        let lat = st.latencies();
        let Some((r_src, r_dst)) = select_pair(&lat, &placement, slot_caps, &invalid) else {
            break;
        };

        // hottest expert on r_src with a movable remote pool
        let Some(e_star) = select_heavy_expert(&a, &placement, r_src, r_dst) else {
            invalid.push((r_src, r_dst));
            continue;
        };
        let fetch_src = pick_source(&placement, e_star, r_dst, fabric, aware);

        // dual-side budget check (eq. 6 vs hiding window): the fetch on
        // r_dst and the slot overwrite (evict) both bound the same slot
        // count; cyclic slot reuse makes |Δ_out| = |Δ_in| per rank. Only
        // NEW fetches are charged — retained replicas already transferred.
        if cfg.enforce_window {
            let slots_after = fetches[r_dst].len() + 1;
            if transfer_time(slots_after, model, hw) > windows[r_dst] {
                invalid.push((r_src, r_dst));
                continue;
            }
            if aware && !fabric.same_node(fetch_src, r_dst) {
                // per-link feasibility for the cross-node path: the
                // flow's own rail line rate + rendezvous latency, then
                // the shared node egress/ingress rail aggregates
                let t_flow = fabric.transfer_time_flow(&Flow {
                    src: fetch_src,
                    dst: r_dst,
                    bytes: expert_bytes,
                });
                if t_flow > windows[r_dst] {
                    invalid.push((r_src, r_dst));
                    continue;
                }
                let ns = fabric.node_of(fetch_src);
                let nd = fabric.node_of(r_dst);
                let t_rail =
                    |slots: usize| slots as f64 * expert_bytes / fabric.rail_bw();
                if t_rail(node_out_slots[ns] + 1) > node_win[ns]
                    || t_rail(node_in_slots[nd] + 1) > node_win[nd]
                {
                    invalid.push((r_src, r_dst));
                    continue;
                }
            }
        }
        if placement.slots_free(r_dst) == 0 || placement.slots_used(r_dst) >= slot_caps[r_dst] {
            invalid.push((r_src, r_dst));
            continue;
        }

        // tentative replica + water-filling rebalance on cloned state
        let before_max = st.max_latency();
        let mut a2 = a.clone();
        let mut st2 = st.clone();
        let moved = water_fill(
            &mut a2,
            &mut st2,
            e_star,
            r_src,
            r_dst,
            model,
            hw,
            cfg.water_filling,
        );
        if moved <= 0.0 {
            invalid.push((r_src, r_dst));
            continue;
        }
        let gain = before_max - st2.max_latency();
        if gain <= eps {
            break; // converged (Algorithm 1 line 12)
        }
        placement
            .add_replica(e_star, r_dst)
            .expect("slot availability pre-checked");
        fetches[r_dst].push(e_star);
        fetch_flows.push(Flow {
            src: fetch_src,
            dst: r_dst,
            bytes: expert_bytes,
        });
        if !fabric.same_node(fetch_src, r_dst) {
            node_out_slots[fabric.node_of(fetch_src)] += 1;
            node_in_slots[fabric.node_of(r_dst)] += 1;
        }
        a = a2;
        st = st2;
    }

    let est_after = st.max_latency();
    PlanOutcome {
        placement,
        assignment: a,
        fetches,
        fetch_flows,
        retained_replicas,
        iterations,
        est_before,
        est_after,
    }
}

/// Pick (argmax, argmin) latency ranks avoiding invalidated pairs; the
/// destination must have a free replica slot within its live memory
/// cap.
fn select_pair(
    lat: &[f64],
    placement: &Placement,
    slot_caps: &[usize],
    invalid: &[(usize, usize)],
) -> Option<(usize, usize)> {
    let ep = lat.len();
    let mut src_order: Vec<usize> = (0..ep).collect();
    src_order.sort_by(|&x, &y| lat[y].partial_cmp(&lat[x]).unwrap());
    let mut dst_order: Vec<usize> = (0..ep).collect();
    dst_order.sort_by(|&x, &y| lat[x].partial_cmp(&lat[y]).unwrap());
    for &s in &src_order {
        for &d in &dst_order {
            if d == s || lat[d] >= lat[s] {
                continue;
            }
            if placement.slots_free(d) == 0
                || placement.slots_used(d) >= slot_caps.get(d).copied().unwrap_or(usize::MAX)
            {
                continue;
            }
            if !invalid.contains(&(s, d)) {
                return Some((s, d));
            }
        }
    }
    None
}

/// Hottest expert executed on `r_src` that is not yet hosted on `r_dst`
/// and has remote tokens available to shed.
fn select_heavy_expert(
    a: &Assignment,
    placement: &Placement,
    r_src: usize,
    r_dst: usize,
) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for e in 0..a.n_experts {
        if !placement.hosts(e, r_src) || placement.hosts(e, r_dst) {
            continue;
        }
        let load = a.tokens_on(e, r_src);
        let movable = a.remote_tokens_on(e, r_src);
        if movable <= 0.0 {
            continue;
        }
        if best.map_or(true, |(_, l)| load > l) {
            best = Some((e, load));
        }
    }
    best.map(|(e, _)| e)
}

/// Locality-aware water-filling (paper §4.3): tokens generated on `r_src`
/// stay pinned; remote tokens are redirected to `r_dst` until `r_src`
/// reaches the cluster average (or the pool empties). The naive ablation
/// variant moves half the pool unconditionally. Updates the incremental
/// latency state alongside the assignment.
#[allow(clippy::too_many_arguments)]
fn water_fill(
    a: &mut Assignment,
    st: &mut LatencyState,
    e_star: usize,
    r_src: usize,
    r_dst: usize,
    model: &MoeModel,
    hw: &HardwareProfile,
    water_filling: bool,
) -> f64 {
    let ep = a.ep;
    let pool: f64 = a.remote_tokens_on(e_star, r_src);
    if pool <= 0.0 {
        return 0.0;
    }
    let target_tokens = if water_filling {
        let lat = st.latencies();
        let avg = lat.iter().sum::<f64>() / ep as f64;
        let excess = (lat[r_src] - avg).max(0.0);
        let marginal = marginal_time(a.tokens_on(e_star, r_src), model, hw);
        if marginal <= 0.0 {
            return 0.0;
        }
        (excess / marginal).min(pool)
    } else {
        pool / 2.0
    };
    if target_tokens <= 0.0 {
        return 0.0;
    }
    // proportional drain across remote sources
    let mut remaining = target_tokens;
    for rs in 0..ep {
        if rs == r_src {
            continue; // locality-first: pinned
        }
        let have = a.get(e_star, rs, r_src);
        if have <= 0.0 {
            continue;
        }
        let share = (have / pool * target_tokens).min(remaining);
        let moved = a.shift(e_star, rs, r_src, r_dst, share);
        st.apply_shift(e_star, rs, r_src, r_dst, moved, model, hw);
        remaining -= moved;
        if remaining <= 1e-9 {
            break;
        }
    }
    target_tokens - remaining
}

/// Re-derive the token assignment for the *actual* routing once the
/// placement is fixed (the router knows the true top-k at dispatch time;
/// only placement had to be decided ahead). Greedy water-filling across
/// the existing replicas; no budget checks (no new transfers happen).
pub fn rebalance_existing(
    counts_by_source: &[Vec<f64>],
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    iters: usize,
) -> Assignment {
    rebalance_existing_on(counts_by_source, placement, model, hw, None, iters)
}

/// [`rebalance_existing`] with optional rail congestion in the objective
/// (topology-aware dispatch rebalancing on multi-node fabrics).
pub fn rebalance_existing_on(
    counts_by_source: &[Vec<f64>],
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
    iters: usize,
) -> Assignment {
    let a = Assignment::locality_first_from_counts(counts_by_source, placement);
    polish_assignment_on(a, placement, model, hw, fabric, iters)
}

/// Iteratively improve an assignment over a FIXED placement: move remote
/// tokens of experts hosted on the bottleneck rank toward their less-
/// loaded replicas (pairwise equalization). Candidates that fail to
/// improve are skipped, not fatal.
pub fn polish_assignment(
    a: Assignment,
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    iters: usize,
) -> Assignment {
    polish_assignment_on(a, placement, model, hw, None, iters)
}

/// [`polish_assignment`] under the fabric-aware objective: with a
/// multi-node fabric the bottleneck metric includes rail congestion, so
/// the polish also sheds cross-node traffic when the rails bind.
pub fn polish_assignment_on(
    mut a: Assignment,
    placement: &Placement,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: Option<&Fabric>,
    iters: usize,
) -> Assignment {
    let mut lat = rank_latencies_on(&a, model, hw, fabric);
    let mut dead: Vec<(usize, usize)> = Vec::new(); // (expert, dst) that failed
    for _ in 0..iters {
        let r_src = argmax(&lat);
        // candidate moves off the bottleneck, best (most movable) first
        let mut cands: Vec<(usize, usize, f64)> = Vec::new();
        for e in 0..a.n_experts {
            if !placement.hosts(e, r_src) {
                continue;
            }
            let movable = a.remote_tokens_on(e, r_src);
            if movable <= 0.0 {
                continue;
            }
            for rt in placement.ranks_hosting(e) {
                if rt == r_src || lat[rt] >= lat[r_src] || dead.contains(&(e, rt)) {
                    continue;
                }
                cands.push((e, rt, movable.min(a.tokens_on(e, r_src))));
            }
        }
        if cands.is_empty() {
            break;
        }
        cands.sort_by(|x, y| y.2.partial_cmp(&x.2).unwrap());
        let mut progressed = false;
        for &(e_star, r_dst, _) in cands.iter().take(4) {
            let mut a2 = a.clone();
            // pairwise equalization: close half the latency gap
            let marginal = marginal_time(a2.tokens_on(e_star, r_src), model, hw);
            if marginal <= 0.0 {
                continue;
            }
            let want = ((lat[r_src] - lat[r_dst]) * 0.5 / marginal).max(0.0);
            let pool = a2.remote_tokens_on(e_star, r_src);
            let target = want.min(pool);
            if target <= 0.0 {
                dead.push((e_star, r_dst));
                continue;
            }
            let mut remaining = target;
            for rs in 0..a2.ep {
                if rs == r_src {
                    continue;
                }
                let have = a2.get(e_star, rs, r_src);
                if have <= 0.0 {
                    continue;
                }
                let moved = a2.shift(e_star, rs, r_src, r_dst, (have / pool * target).min(remaining));
                remaining -= moved;
                if remaining <= 1e-9 {
                    break;
                }
            }
            let lat2 = rank_latencies_on(&a2, model, hw, fabric);
            if lat2[argmax(&lat2)] < lat[r_src] - 1e-12 {
                a = a2;
                lat = lat2;
                progressed = true;
                break;
            }
            dead.push((e_star, r_dst));
        }
        if !progressed {
            break;
        }
    }
    a
}

fn argmax(xs: &[f64]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::RoutingModel;
    use crate::util::stats::imbalance_ratio;

    fn setup(n_tokens: usize, seed: u64) -> (Vec<Vec<f64>>, Placement, MoeModel, HardwareProfile) {
        let model = MoeModel::gpt_oss_120b();
        let mut rm = RoutingModel::calibrated(1, model.n_experts, model.top_k, 3, seed);
        let routing = rm.route_step(&vec![0u16; n_tokens]).layers.remove(0);
        let counts: Vec<Vec<f64>> = routing
            .expert_counts_by_source(8)
            .into_iter()
            .map(|v| v.into_iter().map(|c| c as f64).collect())
            .collect();
        let placement = Placement::sharded(8, model.n_experts, 3);
        (counts, placement, model, HardwareProfile::hopper_141())
    }

    fn wide_windows() -> Vec<f64> {
        vec![1.0; 8] // effectively unconstrained
    }

    #[test]
    fn plan_reduces_bottleneck() {
        let (counts, base, model, hw) = setup(6144, 3);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        assert!(
            out.est_after < out.est_before * 0.95,
            "no improvement: {} -> {}",
            out.est_before,
            out.est_after
        );
        assert!(out.iterations <= cfg.k_max);
        out.placement.validate().unwrap();
    }

    #[test]
    fn plan_conserves_tokens() {
        let (counts, base, model, hw) = setup(2048, 5);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        for e in 0..model.n_experts {
            let want: f64 = counts[e].iter().sum();
            let got = out.assignment.expert_total(e);
            assert!((want - got).abs() < 1e-6, "expert {e}: {want} vs {got}");
        }
    }

    #[test]
    fn plan_respects_slot_budget() {
        let (counts, base, model, hw) = setup(4096, 7);
        let mut cfg = ProbeConfig::default();
        cfg.max_redundant = 1;
        let mut base1 = Placement::sharded(base.ep, base.n_experts, 1);
        base1.clear_replicas();
        let out = plan(&counts, &base1, &model, &hw, &wide_windows(), &cfg);
        for r in 0..8 {
            assert!(out.placement.slots_used(r) <= 1);
        }
    }

    #[test]
    fn tight_window_blocks_replication() {
        let (counts, base, model, hw) = setup(4096, 9);
        let cfg = ProbeConfig::default();
        // window shorter than one expert transfer → no replicas possible
        let w = transfer_time(1, &model, &hw) * 0.5;
        let out = plan(&counts, &base, &model, &hw, &vec![w; 8], &cfg);
        assert_eq!(out.placement.total_replicas(), 0);
        assert_eq!(out.est_after, out.est_before);
    }

    #[test]
    fn window_disabled_ablation_replicates_anyway() {
        let (counts, base, model, hw) = setup(4096, 9);
        let mut cfg = ProbeConfig::default();
        cfg.enforce_window = false;
        let w = transfer_time(1, &model, &hw) * 0.5;
        let out = plan(&counts, &base, &model, &hw, &vec![w; 8], &cfg);
        assert!(out.placement.total_replicas() > 0);
    }

    #[test]
    fn locality_pinned_tokens_never_move() {
        let (counts, base, model, hw) = setup(3072, 11);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        // tokens originating on an expert's home rank stay there
        for e in 0..model.n_experts {
            let home = base.home_rank(e);
            let pinned = counts[e][home];
            assert!(
                (out.assignment.get(e, home, home) - pinned).abs() < 1e-9,
                "expert {e}: pinned tokens moved"
            );
        }
    }

    #[test]
    fn planned_ir_improves() {
        let (counts, base, model, hw) = setup(6144, 13);
        let cfg = ProbeConfig::default();
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        let loads_of = |a: &Assignment| -> Vec<f64> {
            (0..8)
                .map(|r| (0..model.n_experts).map(|e| a.tokens_on(e, r)).sum())
                .collect()
        };
        let before = Assignment::locality_first_from_counts(&counts, &base);
        let ir_b = imbalance_ratio(&loads_of(&before));
        let ir_a = imbalance_ratio(&loads_of(&out.assignment));
        assert!(ir_a < ir_b, "IR {ir_b} -> {ir_a}");
    }

    #[test]
    fn iteration_budget_respected() {
        let (counts, base, model, hw) = setup(8192, 15);
        let mut cfg = ProbeConfig::default();
        cfg.k_max = 2;
        let out = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        assert!(out.iterations <= 2);
        assert!(out.placement.total_replicas() <= 2);
    }

    #[test]
    fn rebalance_existing_respects_placement() {
        let (counts, base, model, hw) = setup(4096, 17);
        let cfg = ProbeConfig::default();
        let planned = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        // re-derive with slightly different (actual) counts
        let mut actual = counts.clone();
        actual[0][0] += 8.0;
        actual[1][0] = (actual[1][0] - 8.0).max(0.0);
        let a = rebalance_existing(&actual, &planned.placement, &model, &hw, 32);
        let counts_u32: Vec<u32> = actual
            .iter()
            .map(|v| v.iter().sum::<f64>() as u32)
            .collect();
        a.validate(&counts_u32, &planned.placement).unwrap();
    }

    #[test]
    fn water_filling_beats_naive_split() {
        let (counts, base, model, hw) = setup(6144, 19);
        let mut cfg = ProbeConfig::default();
        let wf = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        cfg.water_filling = false;
        let naive = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        assert!(
            wf.est_after <= naive.est_after * 1.05,
            "water-filling {} vs naive {}",
            wf.est_after,
            naive.est_after
        );
    }

    #[test]
    fn incremental_state_matches_full_recompute() {
        let (counts, base, model, hw) = setup(4096, 21);
        let mut placement = base.clone();
        placement.add_replica(0, 7).unwrap();
        placement.add_replica(1, 6).unwrap();
        let mut a = Assignment::locality_first_from_counts(&counts, &placement);
        let mut st = LatencyState::from_assignment(&a, &model, &hw);
        // a handful of arbitrary legal shifts, mirrored on the state
        for (e, rs, from, to, x) in [
            (0usize, 2usize, 0usize, 7usize, 5.0f64),
            (0, 3, 0, 7, 11.0),
            (1, 5, 0, 6, 7.0),
            (0, 2, 7, 0, 2.0),
        ] {
            let moved = a.shift(e, rs, from, to, x);
            st.apply_shift(e, rs, from, to, moved, &model, &hw);
        }
        let full = LatencyState::from_assignment(&a, &model, &hw).latencies();
        let inc = st.latencies();
        for (r, (f, i)) in full.iter().zip(&inc).enumerate() {
            assert!((f - i).abs() < 1e-9, "rank {r}: full {f} vs incremental {i}");
        }
    }

    #[test]
    fn delta_plan_reuses_resident_replicas() {
        let (counts, base, model, hw) = setup(6144, 23);
        let cfg = ProbeConfig::default();
        assert!(cfg.delta_plan);
        // first plan from the empty base: everything is a fresh fetch
        let first = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        let first_fetches = first.total_fetches();
        assert!(first_fetches > 0, "first plan fetched nothing");
        assert_eq!(first.retained_replicas, 0);
        // re-plan the SAME predicted counts against the resident
        // placement: the hot replicas are already there — zero fetches
        let second = plan(&counts, &first.placement, &model, &hw, &wide_windows(), &cfg);
        assert!(second.retained_replicas > 0);
        assert!(
            second.total_fetches() < first_fetches,
            "delta plan refetched: {} vs {}",
            second.total_fetches(),
            first_fetches
        );
        // and the balance quality does not regress
        assert!(second.est_after <= first.est_after * 1.05);
        second.placement.validate().unwrap();
    }

    #[test]
    fn fetch_sources_prefer_intra_node() {
        let fabric = Fabric::multi_node_ratio(4, 2, &HardwareProfile::hopper_141(), 0.25, 2);
        let mut p = Placement::sharded(4, 8, 3);
        // expert 0: home rank 0 (node 0), resident replica on rank 2 (node 1)
        p.add_replica(0, 2).unwrap();
        assert_eq!(pick_source(&p, 0, 3, &fabric, true), 2, "same-node copy");
        assert_eq!(pick_source(&p, 0, 3, &fabric, false), 0, "blind reads home");
        assert_eq!(pick_source(&p, 0, 1, &fabric, true), 0, "home is already intra");
        // expert 5 (home rank 2, node 1) fetched into node 0: no intra
        // host exists, fall back to the home shard
        assert_eq!(pick_source(&p, 5, 0, &fabric, true), 2);
    }

    #[test]
    fn rail_infeasible_fetches_stay_intra_node_when_aware() {
        let model = MoeModel::gpt_oss_120b();
        let hw = HardwareProfile::hopper_141();
        let mut rm = RoutingModel::calibrated(1, model.n_experts, model.top_k, 3, 27);
        let routing = rm.route_step(&vec![0u16; 8192]).layers.remove(0);
        let counts: Vec<Vec<f64>> = routing
            .expert_counts_by_source(16)
            .into_iter()
            .map(|v| v.into_iter().map(|c| c as f64).collect())
            .collect();
        let base = Placement::sharded(16, model.n_experts, 3);
        // rails at 1/16 of NVSwitch: a cross-node expert copy takes 16×
        // the window; intra copies fit two slots
        let fabric = Fabric::multi_node_ratio(16, 2, &hw, 1.0 / 16.0, 2);
        let windows = vec![transfer_time(2, &model, &hw); 16];
        let mut cfg = ProbeConfig::default();
        let caps = vec![usize::MAX; 16];
        cfg.topology_aware = true;
        let aware = plan_fabric(&counts, &base, &model, &hw, &fabric, &windows, &caps, &cfg);
        cfg.topology_aware = false;
        let blind = plan_fabric(&counts, &base, &model, &hw, &fabric, &windows, &caps, &cfg);
        assert!(blind.total_fetches() > 0, "blind planner fetched nothing");
        let cross = |o: &PlanOutcome| {
            o.fetch_flows
                .iter()
                .filter(|f| !fabric.same_node(f.src, f.dst))
                .count()
        };
        assert_eq!(cross(&aware), 0, "aware planner scheduled a rail-infeasible fetch");
        assert!(cross(&blind) >= cross(&aware));
        assert_eq!(aware.fetch_flows.len(), aware.total_fetches());
    }

    #[test]
    fn incremental_rail_state_matches_full_recompute() {
        let (counts, base, model, hw) = setup(4096, 29);
        let fabric = Fabric::multi_node_ratio(8, 2, &hw, 0.125, 2);
        let mut placement = base.clone();
        placement.add_replica(0, 7).unwrap();
        placement.add_replica(1, 6).unwrap();
        let mut a = Assignment::locality_first_from_counts(&counts, &placement);
        let mut st = LatencyState::from_assignment_on(&a, &model, &hw, Some(&fabric));
        // shifts that cross and re-cross the node boundary (ranks 0–3
        // node 0, ranks 4–7 node 1)
        for (e, rs, from, to, x) in [
            (0usize, 2usize, 0usize, 7usize, 5.0f64),
            (0, 3, 0, 7, 11.0),
            (1, 5, 0, 6, 7.0),
            (0, 2, 7, 0, 2.0),
        ] {
            let moved = a.shift(e, rs, from, to, x);
            st.apply_shift(e, rs, from, to, moved, &model, &hw);
        }
        let full =
            LatencyState::from_assignment_on(&a, &model, &hw, Some(&fabric)).latencies();
        let inc = st.latencies();
        for (r, (f, i)) in full.iter().zip(&inc).enumerate() {
            assert!((f - i).abs() < 1e-9, "rank {r}: full {f} vs incremental {i}");
        }
    }

    #[test]
    fn slot_caps_bound_replication_per_rank() {
        let (counts, base, model, hw) = setup(6144, 31);
        let cfg = ProbeConfig::default();
        let fabric = Fabric::flat(8, &hw);
        // ragged caps: rank r may hold at most r % 3 replicas
        let caps: Vec<usize> = (0..8).map(|r| r % 3).collect();
        let out = plan_fabric(
            &counts, &base, &model, &hw, &fabric, &wide_windows(), &caps, &cfg,
        );
        for r in 0..8 {
            assert!(
                out.placement.slots_used(r) <= caps[r],
                "rank {r}: {} replicas over cap {}",
                out.placement.slots_used(r),
                caps[r]
            );
        }
        out.placement.validate().unwrap();
        // an all-zero cap vector forbids replication entirely even with
        // wide windows (the KV-pressure endgame)
        let none = plan_fabric(
            &counts, &base, &model, &hw, &fabric, &wide_windows(), &vec![0; 8], &cfg,
        );
        assert_eq!(none.placement.total_replicas(), 0);
        assert_eq!(none.est_after, none.est_before);
    }

    #[test]
    fn shrinking_caps_evict_resident_replicas_monotonically() {
        // replicate under generous headroom, then re-plan the SAME
        // forecast against progressively tighter caps with no fetch
        // budget left (k_max = 0): the resident replica count must
        // shrink monotonically to zero and never exceed any cap — the
        // ISSUE 5 co-balancing tension at planner level
        let (counts, base, model, hw) = setup(6144, 33);
        let mut cfg = ProbeConfig::default();
        assert!(cfg.delta_plan);
        cfg.k_max = 64;
        let fabric = Fabric::flat(8, &hw);
        let generous = plan_fabric(
            &counts,
            &base,
            &model,
            &hw,
            &fabric,
            &wide_windows(),
            &vec![3; 8],
            &cfg,
        );
        assert!(
            generous.placement.total_replicas() > 0,
            "planner never replicated under generous caps"
        );
        cfg.k_max = 0; // pressure phase: evictions only
        let mut resident = generous.placement;
        let mut last_total = resident.total_replicas();
        for cap in (0..3usize).rev() {
            let out = plan_fabric(
                &counts,
                &resident,
                &model,
                &hw,
                &fabric,
                &wide_windows(),
                &vec![cap; 8],
                &cfg,
            );
            let total = out.placement.total_replicas();
            for r in 0..8 {
                assert!(out.placement.slots_used(r) <= cap, "cap {cap} rank {r}");
            }
            assert!(
                total <= last_total,
                "replicas grew as headroom shrank: {last_total} -> {total} at cap {cap}"
            );
            out.placement.validate().unwrap();
            last_total = total;
            resident = out.placement;
        }
        assert_eq!(last_total, 0, "cap 0 must evict every replica");
    }

    #[test]
    fn clear_mode_never_retains() {
        let (counts, base, model, hw) = setup(4096, 25);
        let mut cfg = ProbeConfig::default();
        cfg.delta_plan = false;
        let first = plan(&counts, &base, &model, &hw, &wide_windows(), &cfg);
        let second = plan(&counts, &first.placement, &model, &hw, &wide_windows(), &cfg);
        assert_eq!(second.retained_replicas, 0);
        // clear-every-layer refetches its full replica set
        assert_eq!(second.total_fetches(), second.placement.total_replicas());
    }
}
