//! PJRT runtime: load the AOT artifacts built by `python/compile/aot.py`
//! and execute the real small MoE model from the rust hot path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute_b`. Weights
//! are uploaded to device buffers once at load time; the per-step inputs
//! (tokens, positions, KV cache) are the only recurring host↔device
//! copies. Python never runs here.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

// The PJRT binding is unavailable in the offline/CI crate set: the
// default build uses an API-compatible stub whose client constructor
// errors (Engine::load then fails with a clear message). `--features
// pjrt` expects a real external `xla` crate instead.
#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;
#[cfg(not(feature = "pjrt"))]
use pjrt_stub as xla;

/// Shape/config of the small real model (from `artifacts/metadata.json`).
#[derive(Debug, Clone)]
pub struct SmallModelCfg {
    /// Vocabulary size.
    pub vocab: usize,
    /// Hidden (residual-stream) width.
    pub d_model: usize,
    /// Transformer layers (all MoE).
    pub n_layers: usize,
    /// Experts per layer.
    pub n_experts: usize,
    /// Experts activated per token.
    pub top_k: usize,
    /// Maximum sequence length the KV cache holds.
    pub max_seq: usize,
    /// Sequences per prefill artifact execution.
    pub prefill_batch: usize,
    /// Tokens per prefill chunk.
    pub prefill_chunk: usize,
    /// Decode batch sizes with compiled artifacts.
    pub decode_batches: Vec<usize>,
}

impl SmallModelCfg {
    /// Flat f32 length of the KV cache for `batch` sequences.
    pub fn kv_len(&self, batch: usize) -> usize {
        self.n_layers * 2 * batch * self.max_seq * self.d_model
    }
    /// KV-cache tensor dims `[L, 2, B, S, H]` for `batch` sequences.
    pub fn kv_dims(&self, batch: usize) -> Vec<usize> {
        vec![self.n_layers, 2, batch, self.max_seq, self.d_model]
    }
}

/// One weight tensor's manifest entry.
#[derive(Debug, Clone)]
struct WeightEntry {
    name: String,
    shape: Vec<usize>,
    offset: usize,
    size: usize,
}

/// Outputs of one decode step (all layers).
#[derive(Debug, Clone)]
pub struct DecodeOut {
    /// Decode batch size executed.
    pub batch: usize,
    /// `[B, vocab]` next-token logits.
    pub logits: Vec<f32>,
    /// `[L, B, K]` ground-truth routed experts.
    pub actual_idx: Vec<i32>,
    /// `[L, B, K]` gate weights.
    pub actual_gate: Vec<f32>,
    /// `[L, B, K]` distilled lookahead predictions (-1 on layer 0).
    pub pred_idx: Vec<i32>,
    /// `[L, B, K]` untrained-prior predictions (-1 on layer 0).
    pub prior_idx: Vec<i32>,
    /// Wall-clock of the PJRT execution (incl. host copies).
    pub exec_time: f64,
}

/// Outputs of one prefill chunk.
#[derive(Debug, Clone)]
pub struct PrefillOut {
    /// Prefill batch size executed.
    pub batch: usize,
    /// Chunk length in tokens.
    pub chunk: usize,
    /// `[B, vocab]` logits at the last chunk position.
    pub logits_last: Vec<f32>,
    /// `[L, B, S, K]` ground-truth routed experts.
    pub actual_idx: Vec<i32>,
    /// `[L, B, S, K]` gate weights.
    pub actual_gate: Vec<f32>,
    /// `[L, B, S, K]` distilled lookahead predictions (-1 on layer 0).
    pub pred_idx: Vec<i32>,
    /// `[L, B, S, K]` untrained-prior predictions (-1 on layer 0).
    pub prior_idx: Vec<i32>,
    /// Wall-clock of the PJRT execution (incl. host copies).
    pub exec_time: f64,
}

/// The PJRT engine: one compiled executable per model variant.
pub struct Engine {
    client: xla::PjRtClient,
    cfg: SmallModelCfg,
    weights: Vec<xla::PjRtBuffer>,
    decode: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    prefill: xla::PjRtLoadedExecutable,
    moe_block: xla::PjRtLoadedExecutable,
    n_params: usize,
    /// Per-domain token distributions exported by the build (so serving
    /// traffic matches the distillation corpus); empty when absent.
    domain_dists: Vec<Vec<f64>>,
}

impl Engine {
    /// Load artifacts from `dir` (default `artifacts/`).
    pub fn load(dir: &str) -> Result<Engine> {
        let dir = Path::new(dir);
        let meta_text = std::fs::read_to_string(dir.join("metadata.json")).with_context(|| {
            format!(
                "read {}/metadata.json (run `make artifacts`)",
                dir.display()
            )
        })?;
        let meta = Json::parse(&meta_text).map_err(|e| anyhow!("metadata.json: {e}"))?;
        let m = meta.get("model");
        let cfg = SmallModelCfg {
            vocab: m.get("vocab").as_usize().context("vocab")?,
            d_model: m.get("d_model").as_usize().context("d_model")?,
            n_layers: m.get("n_layers").as_usize().context("n_layers")?,
            n_experts: m.get("n_experts").as_usize().context("n_experts")?,
            top_k: m.get("top_k").as_usize().context("top_k")?,
            max_seq: m.get("max_seq").as_usize().context("max_seq")?,
            prefill_batch: m.get("prefill_batch").as_usize().context("prefill_batch")?,
            prefill_chunk: m.get("prefill_chunk").as_usize().context("prefill_chunk")?,
            decode_batches: vec![4, 8, 16],
        };

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let entries = read_manifest(&dir.join("weights_manifest.json"))?;
        let blob = std::fs::read(dir.join("weights.bin")).context("read weights.bin")?;
        let mut weights = Vec::with_capacity(entries.len());
        for e in &entries {
            let bytes = &blob[e.offset..e.offset + e.size];
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let dims = if e.shape.is_empty() {
                vec![1]
            } else {
                e.shape.clone()
            };
            let buf = client
                .buffer_from_host_buffer::<f32>(&floats, &dims, None)
                .map_err(|err| anyhow!("upload weight {}: {err:?}", e.name))?;
            weights.push(buf);
        }

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto =
                xla::HloModuleProto::from_text_file(path.to_str().context("path utf8")?)
                    .map_err(|e| anyhow!("parse {file}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {file}: {e:?}"))
        };

        let mut decode = BTreeMap::new();
        for &b in &cfg.decode_batches {
            decode.insert(b, compile(&format!("decode_step_b{b}.hlo.txt"))?);
        }
        let prefill = compile(&format!(
            "prefill_b{}_s{}.hlo.txt",
            cfg.prefill_batch, cfg.prefill_chunk
        ))?;
        let moe_block = compile("moe_block_t64.hlo.txt")?;

        // optional: domain token distributions for workload synthesis
        let domain_dists = std::fs::read_to_string(dir.join("domain_dists.json"))
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| {
                j.get("dists").as_arr().map(|rows| {
                    rows.iter()
                        .map(|r| {
                            r.as_arr()
                                .unwrap_or(&[])
                                .iter()
                                .filter_map(|x| x.as_f64())
                                .collect::<Vec<f64>>()
                        })
                        .collect::<Vec<_>>()
                })
            })
            .unwrap_or_default();

        Ok(Engine {
            client,
            n_params: entries.len(),
            cfg,
            weights,
            decode,
            prefill,
            moe_block,
            domain_dists,
        })
    }

    /// Token distribution of a domain (when exported by the build).
    pub fn domain_dist(&self, domain: u16) -> Option<&[f64]> {
        self.domain_dists
            .get(domain as usize)
            .filter(|d| d.len() == self.cfg.vocab)
            .map(|d| d.as_slice())
    }

    /// Shape/config the artifacts were compiled for.
    pub fn cfg(&self) -> &SmallModelCfg {
        &self.cfg
    }

    /// Supported decode batch sizes (compiled variants).
    pub fn decode_batches(&self) -> Vec<usize> {
        self.decode.keys().copied().collect()
    }

    /// Smallest compiled batch ≥ `n` (pad up), or the largest available.
    pub fn pick_batch(&self, n: usize) -> usize {
        self.decode
            .keys()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| *self.decode.keys().last().unwrap())
    }

    /// Run one decode step. `kv` is the cache for the chosen batch and is
    /// updated in place.
    pub fn decode_step(
        &self,
        batch: usize,
        tokens: &[i32],
        pos: &[i32],
        kv: &mut [f32],
    ) -> Result<DecodeOut> {
        let exe = self
            .decode
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode variant for batch {batch}"))?;
        if tokens.len() != batch || pos.len() != batch {
            bail!("tokens/pos must have len {batch}");
        }
        if kv.len() != self.cfg.kv_len(batch) {
            bail!("kv len {} != {}", kv.len(), self.cfg.kv_len(batch));
        }
        let t0 = std::time::Instant::now();
        let tok_b = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[batch], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let pos_b = self
            .client
            .buffer_from_host_buffer::<i32>(pos, &[batch], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let kv_b = self
            .client
            .buffer_from_host_buffer::<f32>(kv, &self.cfg.kv_dims(batch), None)
            .map_err(|e| anyhow!("{e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_b);
        args.push(&pos_b);
        args.push(&kv_b);
        let result = exe.execute_b(&args).map_err(|e| anyhow!("execute: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        if parts.len() != 6 {
            bail!("decode artifact returned {} outputs, want 6", parts.len());
        }
        let logits = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let new_kv = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        kv.copy_from_slice(&new_kv);
        Ok(DecodeOut {
            batch,
            logits,
            actual_idx: parts[2].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            actual_gate: parts[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            pred_idx: parts[4].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            prior_idx: parts[5].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            exec_time: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run one prefill chunk (batch/chunk fixed by the artifact).
    pub fn prefill_chunk(
        &self,
        tokens: &[i32],
        start_pos: &[i32],
        kv: &mut [f32],
    ) -> Result<PrefillOut> {
        let b = self.cfg.prefill_batch;
        let s = self.cfg.prefill_chunk;
        if tokens.len() != b * s {
            bail!("tokens must be [{b},{s}]");
        }
        if kv.len() != self.cfg.kv_len(b) {
            bail!("kv len mismatch");
        }
        let t0 = std::time::Instant::now();
        let tok_b = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[b, s], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let pos_b = self
            .client
            .buffer_from_host_buffer::<i32>(start_pos, &[b], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let kv_b = self
            .client
            .buffer_from_host_buffer::<f32>(kv, &self.cfg.kv_dims(b), None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tok_b);
        args.push(&pos_b);
        args.push(&kv_b);
        let result = self
            .prefill
            .execute_b(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let parts = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("{e:?}"))?;
        if parts.len() != 6 {
            bail!("prefill artifact returned {} outputs, want 6", parts.len());
        }
        let new_kv = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        kv.copy_from_slice(&new_kv);
        Ok(PrefillOut {
            batch: b,
            chunk: s,
            logits_last: parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            actual_idx: parts[2].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            actual_gate: parts[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            pred_idx: parts[4].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            prior_idx: parts[5].to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            exec_time: t0.elapsed().as_secs_f64(),
        })
    }

    /// Run the standalone MoE block (perf microbench): x is `[64, H]`.
    pub fn moe_block(&self, x: &[f32]) -> Result<(Vec<f32>, f64)> {
        let h = self.cfg.d_model;
        if x.len() != 64 * h {
            bail!("x must be [64,{h}]");
        }
        let t0 = std::time::Instant::now();
        let x_b = self
            .client
            .buffer_from_host_buffer::<f32>(x, &[64, h], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&x_b);
        let result = self
            .moe_block
            .execute_b(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let parts = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_tuple()
            .map_err(|e| anyhow!("{e:?}"))?;
        let y = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((y, t0.elapsed().as_secs_f64()))
    }

    /// Number of weight tensors uploaded at load time.
    pub fn n_params(&self) -> usize {
        self.n_params
    }
}

fn read_manifest(path: &Path) -> Result<Vec<WeightEntry>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
    let params = j.get("params").as_arr().context("manifest params array")?;
    let mut out = Vec::with_capacity(params.len());
    for p in params {
        out.push(WeightEntry {
            name: p.get("name").as_str().context("name")?.to_string(),
            shape: p
                .get("shape")
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect(),
            offset: p.get("offset_bytes").as_usize().context("offset")?,
            size: p.get("size_bytes").as_usize().context("size")?,
        });
    }
    Ok(out)
}

/// Ground-truth routing extracted from a decode step.
pub fn routing_from_decode(
    out: &DecodeOut,
    cfg: &SmallModelCfg,
) -> Vec<crate::routing::LayerRouting> {
    split_routing_opt(&out.actual_idx, cfg, out.batch, 1)
        .into_iter()
        .map(|o| o.expect("ground-truth routing has no sentinel layers"))
        .collect()
}

/// Lookahead predictions from a decode step (None on layer 0: the -1
/// sentinel — no lookahead source exists for the first layer).
pub fn predictions_from_decode(
    out: &DecodeOut,
    cfg: &SmallModelCfg,
) -> Vec<Option<crate::routing::LayerRouting>> {
    split_routing_opt(&out.pred_idx, cfg, out.batch, 1)
}

/// Untrained-prior predictions (Fig. 10 baseline).
pub fn priors_from_decode(
    out: &DecodeOut,
    cfg: &SmallModelCfg,
) -> Vec<Option<crate::routing::LayerRouting>> {
    split_routing_opt(&out.prior_idx, cfg, out.batch, 1)
}

fn split_routing_opt(
    idx: &[i32],
    cfg: &SmallModelCfg,
    batch: usize,
    seq: usize,
) -> Vec<Option<crate::routing::LayerRouting>> {
    let k = cfg.top_k;
    let per_layer = batch * seq * k;
    assert_eq!(idx.len(), cfg.n_layers * per_layer);
    (0..cfg.n_layers)
        .map(|l| {
            let slice = &idx[l * per_layer..(l + 1) * per_layer];
            if slice.iter().any(|&e| e < 0) {
                return None;
            }
            Some(crate::routing::LayerRouting::new(
                batch * seq,
                k,
                cfg.n_experts,
                slice.iter().map(|&e| e as u16).collect(),
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    // Engine tests live in rust/tests/runtime_e2e.rs (they need built
    // artifacts); here we test the manifest/routing helpers only.
    use super::*;

    fn cfg() -> SmallModelCfg {
        SmallModelCfg {
            vocab: 512,
            d_model: 128,
            n_layers: 2,
            n_experts: 16,
            top_k: 2,
            max_seq: 160,
            prefill_batch: 4,
            prefill_chunk: 32,
            decode_batches: vec![4, 8, 16],
        }
    }

    #[test]
    fn split_routing_shapes() {
        let c = cfg();
        let idx: Vec<i32> = (0..(2 * 3 * 2)).map(|i| (i % 16) as i32).collect();
        let layers = split_routing_opt(&idx, &c, 3, 1);
        assert_eq!(layers.len(), 2);
        let l0 = layers[0].as_ref().unwrap();
        assert_eq!(l0.n_tokens, 3);
        assert_eq!(l0.top_k, 2);
    }

    #[test]
    fn sentinel_layers_become_none() {
        let c = cfg();
        let mut idx: Vec<i32> = vec![1; 2 * 3 * 2];
        idx[0] = -1;
        let layers = split_routing_opt(&idx, &c, 3, 1);
        assert!(layers[0].is_none());
        assert!(layers[1].is_some());
    }

    #[test]
    fn kv_len_formula() {
        let c = cfg();
        assert_eq!(c.kv_len(4), 2 * 2 * 4 * 160 * 128);
        assert_eq!(c.kv_dims(8), vec![2, 2, 8, 160, 128]);
    }
}
