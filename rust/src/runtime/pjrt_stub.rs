//! Offline stand-in for the PJRT `xla` binding.
//!
//! The container/CI image has no PJRT runtime, so the default build
//! compiles against this API-compatible stub: every entry point returns
//! an "unavailable" error, which [`super::Engine::load`] surfaces as a
//! clear message (`probe serve` and `examples/e2e_serving.rs` then fail
//! gracefully, and `rust/tests/runtime_e2e.rs` skips — exactly as when
//! artifacts are missing). Building with `--features pjrt` swaps in a
//! real `xla` crate (vendored PJRT binding, see DESIGN.md) instead.

/// Error returned by every stub entry point.
#[derive(Debug, Clone)]
pub struct PjRtUnavailable;

impl std::fmt::Display for PjRtUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT backend not linked in this build (enable the `pjrt` \
             feature with a vendored xla binding)"
        )
    }
}

type Out<T> = Result<T, PjRtUnavailable>;

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Out<PjRtClient> {
        Err(PjRtUnavailable)
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Out<PjRtBuffer> {
        Err(PjRtUnavailable)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Out<PjRtLoadedExecutable> {
        Err(PjRtUnavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Out<Literal> {
        Err(PjRtUnavailable)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Out<Vec<Vec<PjRtBuffer>>> {
        Err(PjRtUnavailable)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Out<HloModuleProto> {
        Err(PjRtUnavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn to_tuple(&self) -> Out<Vec<Literal>> {
        Err(PjRtUnavailable)
    }

    pub fn to_vec<T>(&self) -> Out<Vec<T>> {
        Err(PjRtUnavailable)
    }
}
