//! Token→expert routing representations and the synthetic routing model.
//!
//! A [`LayerRouting`] is the ground-truth router output for one MoE layer
//! of one step: for each of `n_tokens` tokens, `top_k` expert ids. Tokens
//! are block-distributed across DP/attention ranks (token t lives on rank
//! `t / tokens_per_rank`), matching the hybrid DP-attention + EP-MoE
//! deployment the paper models (§3.1).

use crate::util::Rng;

pub mod capacity;
pub use capacity::{CapacityEnforcer, CapacityLayerStats, CapacityStepStats, CapacityStepView};

/// Sentinel expert id marking a routing slot vacated by capacity
/// enforcement (dropped or queued to the next step). Never a valid
/// expert id: layers are capped far below `u16::MAX` experts. Every
/// consumer of `experts` skips it; with capacity off the sentinel never
/// appears, so the skip guards cannot perturb the pre-capacity model.
pub const DROPPED: u16 = u16::MAX;

/// Ground-truth routing of one MoE layer for one step.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRouting {
    /// Tokens routed this layer.
    pub n_tokens: usize,
    /// Experts activated per token.
    pub top_k: usize,
    /// Experts in the layer.
    pub n_experts: usize,
    /// Flat `[n_tokens * top_k]`, token-major; distinct within a token.
    pub experts: Vec<u16>,
}

impl LayerRouting {
    /// Wrap a flat expert-id buffer (asserts the shape).
    pub fn new(n_tokens: usize, top_k: usize, n_experts: usize, experts: Vec<u16>) -> LayerRouting {
        assert_eq!(experts.len(), n_tokens * top_k);
        debug_assert!(experts
            .iter()
            .all(|&e| (e as usize) < n_experts || e == DROPPED));
        LayerRouting {
            n_tokens,
            top_k,
            n_experts,
            experts,
        }
    }

    /// Expert ids chosen by token `t`.
    #[inline]
    pub fn token_experts(&self, t: usize) -> &[u16] {
        &self.experts[t * self.top_k..(t + 1) * self.top_k]
    }

    /// Global tokens per expert (n_e in the paper). [`DROPPED`]
    /// sentinel slots are not counted anywhere.
    pub fn expert_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.n_experts];
        for &e in &self.experts {
            if e == DROPPED {
                continue;
            }
            counts[e as usize] += 1;
        }
        counts
    }

    /// Tokens per expert per source rank: `[expert][rank]` (n_e^{r_s}).
    pub fn expert_counts_by_source(&self, ep: usize) -> Vec<Vec<u32>> {
        let mut counts = vec![vec![0u32; ep]; self.n_experts];
        for t in 0..self.n_tokens {
            let rs = token_rank(t, self.n_tokens, ep);
            for &e in self.token_experts(t) {
                if e == DROPPED {
                    continue;
                }
                counts[e as usize][rs] += 1;
            }
        }
        counts
    }

    /// [`Self::expert_counts_by_source`] as f64 — the planner's and the
    /// lookahead predictors' input format.
    pub fn expert_counts_by_source_f64(&self, ep: usize) -> Vec<Vec<f64>> {
        self.expert_counts_by_source(ep)
            .into_iter()
            .map(|v| v.into_iter().map(f64::from).collect())
            .collect()
    }

    /// Tokens per expert per source rank, written into a caller-provided
    /// flat buffer `out[e * ep + rs]` (f64): the zero-allocation variant
    /// of [`Self::expert_counts_by_source_f64`] for the per-layer
    /// observe/decide hot path (ISSUE 6). The buffer is cleared and
    /// resized in place, so a reused buffer never reallocates once it
    /// has grown to the layer's `n_experts * ep`.
    pub fn expert_counts_by_source_into(&self, ep: usize, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.n_experts * ep, 0.0);
        for t in 0..self.n_tokens {
            let rs = token_rank(t, self.n_tokens, ep);
            for &e in self.token_experts(t) {
                if e == DROPPED {
                    continue;
                }
                out[e as usize * ep + rs] += 1.0;
            }
        }
    }
}

/// Rank owning token `t` under block distribution.
#[inline]
pub fn token_rank(t: usize, n_tokens: usize, ep: usize) -> usize {
    debug_assert!(t < n_tokens);
    // ceil-divided blocks so every rank gets ±1 of n/ep.
    let per = n_tokens.div_ceil(ep);
    (t / per).min(ep - 1)
}

/// Routing for all MoE layers of one step.
#[derive(Debug, Clone)]
pub struct StepRouting {
    /// One routing per MoE layer, in execution order.
    pub layers: Vec<LayerRouting>,
}

/// Synthetic semantic routing model (DESIGN.md substitutions): each
/// (domain, layer) has a Dirichlet-drawn expert-affinity distribution.
/// Token top-k draws without replacement from a blend of its domain
/// affinity and uniform noise; domain affinities drift over steps.
#[derive(Debug, Clone)]
pub struct RoutingModel {
    /// MoE layers modeled.
    pub n_layers: usize,
    /// Experts per layer.
    pub n_experts: usize,
    /// Experts activated per token.
    pub top_k: usize,
    /// Semantic domains with distinct expert affinities.
    pub n_domains: usize,
    /// `[layer][domain][expert]` affinity (sums to 1).
    affinity: Vec<Vec<Vec<f64>>>,
    /// Dirichlet concentration: lower = more skew.
    pub alpha: f64,
    /// Per-step drift rate: fraction of affinity replaced by a fresh draw.
    pub drift: f64,
    /// Weight of per-token uniform exploration vs domain affinity.
    pub noise: f64,
    rng: Rng,
}

impl RoutingModel {
    /// Routing model with explicit skew (`alpha`), drift, and noise.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n_layers: usize,
        n_experts: usize,
        top_k: usize,
        n_domains: usize,
        alpha: f64,
        drift: f64,
        noise: f64,
        seed: u64,
    ) -> RoutingModel {
        let mut rng = Rng::new(seed);
        let alpha_vec = vec![alpha; n_experts];
        let affinity = (0..n_layers)
            .map(|_| {
                (0..n_domains)
                    .map(|_| rng.next_dirichlet(&alpha_vec))
                    .collect()
            })
            .collect();
        RoutingModel {
            n_layers,
            n_experts,
            top_k,
            n_domains,
            affinity,
            alpha,
            drift,
            noise,
            rng,
        }
    }

    /// Calibrated to the paper's measured skew for a GPT-OSS-like model
    /// (Fig. 2: prefill IR spikes > 2.6, decode IR 1.43–2.28 at ep=8).
    pub fn calibrated(
        n_layers: usize,
        n_experts: usize,
        top_k: usize,
        n_domains: usize,
        seed: u64,
    ) -> RoutingModel {
        RoutingModel::new(
            n_layers, n_experts, top_k, n_domains,
            /*alpha=*/ 0.02, /*drift=*/ 0.04, /*noise=*/ 0.18, seed,
        )
    }

    /// Advance the semantic drift process one decode step.
    pub fn step_drift(&mut self) {
        if self.drift <= 0.0 {
            return;
        }
        let alpha_vec = vec![self.alpha; self.n_experts];
        for layer in 0..self.n_layers {
            for d in 0..self.n_domains {
                // occasionally re-draw (hotspot migration), otherwise mix
                if self.rng.next_f64() < self.drift {
                    let fresh = self.rng.next_dirichlet(&alpha_vec);
                    let a = &mut self.affinity[layer][d];
                    for (x, f) in a.iter_mut().zip(fresh) {
                        *x = 0.5 * *x + 0.5 * f;
                    }
                }
            }
        }
    }

    /// Affinity vector (for the statistical predictor's hotspot view).
    pub fn affinity(&self, layer: usize, domain: usize) -> &[f64] {
        &self.affinity[layer][domain]
    }

    /// Route one step: `token_domains[t]` gives each token's domain.
    ///
    /// Hot path of every simulation sweep. Per (layer, domain) the
    /// blended weights are fixed within a step, so we precompute their
    /// CDF once and sample by binary search with rejection for the
    /// without-replacement constraint (O(k log E) per token instead of
    /// O(k·E) linear scans) — §Perf, ~5× faster at paper scale.
    pub fn route_step(&mut self, token_domains: &[u16]) -> StepRouting {
        let n = token_domains.len();
        let uniform = 1.0 / self.n_experts as f64;
        let mut layers = Vec::with_capacity(self.n_layers);
        let mut weights = vec![0.0f64; self.n_experts];
        let mut cdf = vec![0.0f64; self.n_experts];
        for layer in 0..self.n_layers {
            // per-domain CDFs for this layer
            let mut domain_cdf: Vec<Vec<f64>> = Vec::with_capacity(self.n_domains);
            let mut domain_w: Vec<Vec<f64>> = Vec::with_capacity(self.n_domains);
            for d in 0..self.n_domains {
                let aff = &self.affinity[layer][d];
                let mut acc = 0.0;
                for (e, &a) in aff.iter().enumerate() {
                    weights[e] = (1.0 - self.noise) * a + self.noise * uniform;
                    acc += weights[e];
                    cdf[e] = acc;
                }
                domain_cdf.push(cdf.clone());
                domain_w.push(weights.clone());
            }
            let mut experts = Vec::with_capacity(n * self.top_k);
            for &d in token_domains {
                self.sample_topk_cdf(&domain_cdf[d as usize], &domain_w[d as usize], &mut experts);
            }
            layers.push(LayerRouting::new(n, self.top_k, self.n_experts, experts));
        }
        StepRouting { layers }
    }

    /// Draw `top_k` distinct experts via CDF binary search with bounded
    /// rejection; falls back to a linear without-replacement scan when
    /// collisions persist (extreme skew).
    fn sample_topk_cdf(&mut self, cdf: &[f64], weights: &[f64], out: &mut Vec<u16>) {
        let start = out.len();
        let total = *cdf.last().unwrap();
        'slots: for _ in 0..self.top_k {
            for _try in 0..16 {
                let x = self.rng.next_f64() * total;
                let e = cdf.partition_point(|&c| c < x).min(cdf.len() - 1) as u16;
                if !out[start..].contains(&e) {
                    out.push(e);
                    continue 'slots;
                }
            }
            // fallback: exact without-replacement linear draw
            let chosen = &out[start..];
            let mut w: Vec<f64> = weights.to_vec();
            for &c in chosen {
                w[c as usize] = 0.0;
            }
            let e = self.rng.next_weighted(&w) as u16;
            out.push(e);
        }
        debug_assert_eq!(out.len(), start + self.top_k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::imbalance_ratio;

    fn model() -> RoutingModel {
        RoutingModel::calibrated(4, 32, 4, 3, 7)
    }

    #[test]
    fn routing_shape_and_validity() {
        let mut m = model();
        let domains = vec![0u16; 100];
        let step = m.route_step(&domains);
        assert_eq!(step.layers.len(), 4);
        for l in &step.layers {
            assert_eq!(l.experts.len(), 100 * 4);
            assert!(l.experts.iter().all(|&e| (e as usize) < 32));
        }
    }

    #[test]
    fn topk_distinct_per_token() {
        let mut m = model();
        let step = m.route_step(&vec![1u16; 50]);
        for l in &step.layers {
            for t in 0..50 {
                let es = l.token_experts(t);
                let mut s = es.to_vec();
                s.sort_unstable();
                s.dedup();
                assert_eq!(s.len(), es.len());
            }
        }
    }

    #[test]
    fn expert_counts_conserve_tokens() {
        let mut m = model();
        let step = m.route_step(&vec![2u16; 64]);
        let counts = step.layers[0].expert_counts();
        assert_eq!(counts.iter().sum::<u32>() as usize, 64 * 4);
    }

    #[test]
    fn counts_by_source_conserve() {
        let mut m = model();
        let step = m.route_step(&vec![0u16; 64]);
        let by_src = step.layers[0].expert_counts_by_source(8);
        let total: u32 = by_src.iter().flat_map(|v| v.iter()).sum();
        assert_eq!(total as usize, 64 * 4);
    }

    #[test]
    fn counts_into_matches_nested() {
        let mut m = model();
        let step = m.route_step(&vec![1u16; 100]);
        let lr = &step.layers[0];
        let ep = 8;
        let nested = lr.expert_counts_by_source_f64(ep);
        let mut flat = vec![1e9; 3]; // stale garbage must be cleared
        lr.expert_counts_by_source_into(ep, &mut flat);
        assert_eq!(flat.len(), lr.n_experts * ep);
        for e in 0..lr.n_experts {
            for rs in 0..ep {
                assert_eq!(flat[e * ep + rs], nested[e][rs]);
            }
        }
    }

    #[test]
    fn token_rank_blocks() {
        assert_eq!(token_rank(0, 64, 8), 0);
        assert_eq!(token_rank(7, 64, 8), 0);
        assert_eq!(token_rank(8, 64, 8), 1);
        assert_eq!(token_rank(63, 64, 8), 7);
        // ragged: 10 tokens over 8 ranks -> blocks of 2, token 9 on rank 4
        assert_eq!(token_rank(9, 10, 8), 4);
        assert_eq!(token_rank(0, 1, 8), 0);
    }

    #[test]
    fn single_domain_is_skewed_mixed_is_flatter() {
        // semantic clustering: one domain concentrates experts (prefill
        // burst); mixing domains flattens the aggregate (decode).
        let mut m = RoutingModel::calibrated(1, 128, 4, 4, 11);
        let n = 4096;
        let single = m.route_step(&vec![0u16; n]);
        let mixed_domains: Vec<u16> = (0..n).map(|i| (i % 4) as u16).collect();
        let mixed = m.route_step(&mixed_domains);
        let ir_of = |lr: &LayerRouting| {
            // aggregate to ep=8 ranks of 16 experts each
            let counts = lr.expert_counts();
            let loads: Vec<f64> = (0..8)
                .map(|r| counts[r * 16..(r + 1) * 16].iter().sum::<u32>() as f64)
                .collect();
            imbalance_ratio(&loads)
        };
        assert!(
            ir_of(&single.layers[0]) > ir_of(&mixed.layers[0]),
            "single {} <= mixed {}",
            ir_of(&single.layers[0]),
            ir_of(&mixed.layers[0])
        );
    }

    #[test]
    fn drift_changes_affinity() {
        let mut m = model();
        let before = m.affinity(0, 0).to_vec();
        for _ in 0..200 {
            m.step_drift();
        }
        let after = m.affinity(0, 0);
        let delta: f64 = before
            .iter()
            .zip(after)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(delta > 1e-3, "no drift: {delta}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = RoutingModel::calibrated(2, 16, 2, 2, 5);
        let mut b = RoutingModel::calibrated(2, 16, 2, 2, 5);
        let d = vec![0u16; 20];
        assert_eq!(a.route_step(&d).layers[0], b.route_step(&d).layers[0]);
    }
}
