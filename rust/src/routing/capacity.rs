//! Per-expert capacity enforcement (ISSUE 9).
//!
//! Real serving stacks bound every expert by a capacity factor: with
//! `T` tokens routing `k` experts each over `E` experts, each expert
//! accepts at most `cap = ⌈C·kT/E⌉` slots per layer (SNIPPETS.md §2).
//! Slots beyond the cap are handled by the configured overflow policy:
//!
//! - `drop`    — the slot is discarded (the token loses one expert).
//! - `reroute` — the slot is re-assigned to the next-ranked under-cap
//!               expert (cyclic scan from the chosen id), keeping the
//!               within-token distinctness invariant; if every expert
//!               is at cap the slot is dropped.
//! - `queue`   — the slot is carried to the same layer of the NEXT
//!               step, where it is admitted ahead of fresh traffic
//!               (FIFO) and charged to its original source rank.
//!
//! The enforcer sits between the router and the balancer: it rewrites
//! the ground-truth [`StepRouting`] into an *admitted* routing of the
//! identical `(n_tokens, top_k)` shape, marking vacated slots with the
//! [`DROPPED`](super::DROPPED) sentinel. With `factor = ∞` the cap
//! saturates and the admitted routing is bit-identical to the input —
//! the equivalence `tests/capacity_invariants.rs` pins.

use crate::config::{CapacityConfig, CapacityPolicy};

use super::{token_rank, LayerRouting, StepRouting, DROPPED};

/// Per-layer enforcement accounting. Conservation invariants:
/// `admitted + dropped + queued == offered` (fresh slots) and
/// `carried_admitted + requeued == carried_in` (backlog slots).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CapacityLayerStats {
    /// Fresh routing slots offered this layer (`n_tokens * top_k`).
    pub offered: u32,
    /// Fresh slots admitted in place or via reroute.
    pub admitted: u32,
    /// Fresh slots admitted at a rewritten expert id (subset of
    /// `admitted`).
    pub rerouted: u32,
    /// Fresh slots discarded (drop policy, or reroute with every
    /// expert at cap).
    pub dropped: u32,
    /// Fresh slots deferred to the next step (queue policy).
    pub queued: u32,
    /// Backlog slots carried in from the previous step.
    pub carried_in: u32,
    /// Backlog slots admitted this layer.
    pub carried_admitted: u32,
    /// Backlog slots still over cap, re-queued for the next step.
    pub requeued: u32,
}

/// Whole-step enforcement totals (sum of the per-layer stats).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CapacityStepStats {
    /// Fresh slots offered across all layers.
    pub offered: u64,
    /// Fresh slots admitted across all layers.
    pub admitted: u64,
    /// Admitted at a rewritten expert id.
    pub rerouted: u64,
    /// Discarded slots.
    pub dropped: u64,
    /// Slots deferred to the next step (fresh + re-queued backlog).
    pub queued: u64,
    /// Backlog slots admitted this step.
    pub carried_admitted: u64,
}

/// Result of enforcing one step: the admitted routing plus everything
/// the executor needs to charge backlog compute, attribute drops to
/// tenants, and emit telemetry.
#[derive(Debug, Clone)]
pub struct CapacityStepView {
    /// Admitted routing — same shape as the input, vacated slots hold
    /// the [`DROPPED`](super::DROPPED) sentinel.
    pub routing: StepRouting,
    /// Per layer: backlog slots admitted this step as
    /// `(expert, source rank)` — extra compute the balancer's
    /// assignment must be charged with after `decide`.
    pub carried: Vec<Vec<(u16, u16)>>,
    /// Per-layer accounting.
    pub layer_stats: Vec<CapacityLayerStats>,
    /// Per-layer cap actually applied (`u32::MAX` when unbounded).
    pub caps: Vec<u32>,
    /// Slots dropped per token, summed over layers — the hook for
    /// per-tenant drop-rate attribution.
    pub dropped_per_token: Vec<u32>,
}

impl CapacityStepView {
    /// Sum the per-layer stats into whole-step totals.
    pub fn totals(&self) -> CapacityStepStats {
        let mut t = CapacityStepStats::default();
        for s in &self.layer_stats {
            t.offered += u64::from(s.offered);
            t.admitted += u64::from(s.admitted);
            t.rerouted += u64::from(s.rerouted);
            t.dropped += u64::from(s.dropped);
            t.queued += u64::from(s.queued) + u64::from(s.requeued);
            t.carried_admitted += u64::from(s.carried_admitted);
        }
        t
    }
}

/// Stateful per-expert capacity enforcer. State is the per-layer
/// backlog of queued slots; everything else is recomputed per step, so
/// replaying an identical stream through a fresh enforcer reproduces
/// identical admitted routings and event streams bit-for-bit.
#[derive(Debug, Clone)]
pub struct CapacityEnforcer {
    factor: f64,
    policy: CapacityPolicy,
    ep: usize,
    /// Per layer, FIFO backlog of queued slots `(expert, source rank)`.
    pending: Vec<Vec<(u16, u16)>>,
    /// Scratch: admitted count per expert for the layer in flight.
    counts: Vec<u32>,
    /// Under-cap candidate ring for reroute (ISSUE 10): `ring_next[i]`
    /// is the next candidate to try after `i` in cyclic id order,
    /// path-compressed past at-cap experts as caps fill, so a reroute
    /// walks only live candidates instead of rescanning all E experts.
    /// Rebuilt per layer (experts never come back under cap within a
    /// layer — counts only grow).
    ring_next: Vec<u16>,
    /// Experts still under cap in the layer in flight (0 ⇒ every
    /// reroute fails fast).
    under_cap: usize,
    /// Test hook: use the original full-scan reroute lookup instead of
    /// the ring (bit-parity gates in `tests/capacity_invariants.rs`).
    scan_reroute: bool,
}

impl CapacityEnforcer {
    /// Enforcer for `n_layers` MoE layers on an `ep`-rank cluster.
    pub fn new(cfg: &CapacityConfig, n_layers: usize, ep: usize) -> CapacityEnforcer {
        CapacityEnforcer {
            factor: cfg.factor,
            policy: cfg.policy,
            ep,
            pending: vec![Vec::new(); n_layers],
            counts: Vec::new(),
            ring_next: Vec::new(),
            under_cap: 0,
            scan_reroute: false,
        }
    }

    /// Force the O(E)-scan reroute lookup the candidate ring replaced.
    /// Test-only escape hatch: the parity gates replay identical
    /// streams through ring and scan enforcers and require bit-equal
    /// admitted routings.
    #[doc(hidden)]
    pub fn force_scan_reroute(&mut self) {
        self.scan_reroute = true;
    }

    /// Admit one slot on expert `e`, maintaining the under-cap count
    /// the reroute ring fails fast on. Callers guarantee
    /// `counts[e] < cap` beforehand.
    #[inline]
    fn admit(&mut self, e: usize, cap: u32) {
        self.counts[e] += 1;
        if self.counts[e] == cap {
            self.under_cap -= 1;
        }
    }

    /// First under-cap expert reachable from `start` in cyclic id
    /// order, compressing the ring past at-cap experts on the way. Must
    /// only be called with `under_cap > 0` (guaranteed to terminate:
    /// the initial ring is the full id cycle and compression only skips
    /// dead experts, so every live expert stays reachable).
    fn ring_find(&mut self, start: usize, cap: u32) -> usize {
        let mut p = start;
        while self.counts[p] >= cap {
            p = self.ring_next[p] as usize;
        }
        let mut q = start;
        while self.counts[q] >= cap {
            let nxt = self.ring_next[q] as usize;
            self.ring_next[q] = p as u16;
            q = nxt;
        }
        p
    }

    /// Ring-backed replacement for [`next_ranked_scan`]: identical
    /// result (the scan's candidate order restricted to under-cap
    /// experts IS the ring order), but each lookup touches only live
    /// candidates plus the compressed path. `e` itself is at cap —
    /// that's why the lookup ran — so the ring can never return it.
    fn next_ranked_ring(&mut self, e: u16, cap: u32, token_slots: &[u16]) -> Option<u16> {
        if self.under_cap == 0 {
            return None;
        }
        let n = self.counts.len();
        let first = self.ring_find((e as usize + 1) % n, cap);
        let mut cand = first;
        loop {
            if !token_slots.contains(&(cand as u16)) {
                return Some(cand as u16);
            }
            cand = self.ring_find(self.ring_next[cand] as usize, cap);
            if cand == first {
                return None; // every live candidate is already in the token
            }
        }
    }

    /// Whether enforcement is active (`factor > 0`; `∞` counts as
    /// active with an unbounded cap).
    pub fn enabled(&self) -> bool {
        self.factor > 0.0
    }

    /// Queued slots currently awaiting admission across all layers.
    pub fn backlog(&self) -> usize {
        self.pending.iter().map(Vec::len).sum()
    }

    /// Per-layer cap for a layer routing `k` slots per token over `t`
    /// tokens and `e` experts: `⌈C·kt/e⌉`, saturating for `C = ∞`.
    pub fn cap_for(&self, n_tokens: usize, top_k: usize, n_experts: usize) -> u32 {
        if self.factor.is_infinite() {
            return u32::MAX;
        }
        let slots = (n_tokens * top_k) as f64;
        // `as` saturates, so absurd factors degrade to "unbounded"
        (self.factor * slots / n_experts as f64).ceil() as u32
    }

    /// Enforce the caps on one step's ground-truth routing.
    pub fn enforce_step(&mut self, routing: &StepRouting) -> CapacityStepView {
        let n_layers = routing.layers.len();
        debug_assert_eq!(n_layers, self.pending.len());
        let n_tokens = routing.layers.first().map_or(0, |l| l.n_tokens);
        let mut view = CapacityStepView {
            routing: StepRouting {
                layers: Vec::with_capacity(n_layers),
            },
            carried: Vec::with_capacity(n_layers),
            layer_stats: Vec::with_capacity(n_layers),
            caps: Vec::with_capacity(n_layers),
            dropped_per_token: vec![0; n_tokens],
        };
        for (l, lr) in routing.layers.iter().enumerate() {
            let (admitted, carried, stats, cap) = self.enforce_layer(l, lr, &mut view.dropped_per_token);
            view.routing.layers.push(admitted);
            view.carried.push(carried);
            view.layer_stats.push(stats);
            view.caps.push(cap);
        }
        view
    }

    /// Enforce one layer: admit the backlog FIFO, then fresh slots in
    /// token/slot order. Returns the admitted routing, the admitted
    /// backlog slots, the accounting, and the cap applied.
    fn enforce_layer(
        &mut self,
        layer: usize,
        lr: &LayerRouting,
        dropped_per_token: &mut [u32],
    ) -> (LayerRouting, Vec<(u16, u16)>, CapacityLayerStats, u32) {
        let cap = self.cap_for(lr.n_tokens, lr.top_k, lr.n_experts);
        let mut stats = CapacityLayerStats {
            offered: (lr.n_tokens * lr.top_k) as u32,
            ..CapacityLayerStats::default()
        };
        self.counts.clear();
        self.counts.resize(lr.n_experts, 0);
        self.under_cap = if cap == 0 { 0 } else { lr.n_experts };
        if matches!(self.policy, CapacityPolicy::Reroute) && !self.scan_reroute {
            // fresh full id cycle; compression shortens it as caps fill
            self.ring_next.clear();
            self.ring_next
                .extend((0..lr.n_experts).map(|i| ((i + 1) % lr.n_experts) as u16));
        }

        // backlog first: FIFO, ahead of fresh traffic
        let backlog = std::mem::take(&mut self.pending[layer]);
        stats.carried_in = backlog.len() as u32;
        let mut carried = Vec::new();
        let mut requeue = Vec::new();
        for (e, rs) in backlog {
            if self.counts[e as usize] < cap {
                self.admit(e as usize, cap);
                stats.carried_admitted += 1;
                carried.push((e, rs));
            } else {
                stats.requeued += 1;
                requeue.push((e, rs));
            }
        }

        // fresh slots in token/slot order
        let mut experts = lr.experts.clone();
        for t in 0..lr.n_tokens {
            for j in 0..lr.top_k {
                let idx = t * lr.top_k + j;
                let e = experts[idx];
                if self.counts[e as usize] < cap {
                    self.admit(e as usize, cap);
                    stats.admitted += 1;
                    continue;
                }
                match self.policy {
                    CapacityPolicy::Drop => {
                        experts[idx] = DROPPED;
                        stats.dropped += 1;
                        dropped_per_token[t] += 1;
                    }
                    CapacityPolicy::Reroute => {
                        let slot = &experts[t * lr.top_k..(t + 1) * lr.top_k];
                        let alt = if self.scan_reroute {
                            next_ranked_scan(e, cap, &self.counts, slot)
                        } else {
                            self.next_ranked_ring(e, cap, slot)
                        };
                        match alt {
                            Some(alt) => {
                                experts[idx] = alt;
                                self.admit(alt as usize, cap);
                                stats.admitted += 1;
                                stats.rerouted += 1;
                            }
                            None => {
                                experts[idx] = DROPPED;
                                stats.dropped += 1;
                                dropped_per_token[t] += 1;
                            }
                        }
                    }
                    CapacityPolicy::Queue => {
                        experts[idx] = DROPPED;
                        stats.queued += 1;
                        let rs = token_rank(t, lr.n_tokens, self.ep) as u16;
                        requeue.push((e, rs));
                    }
                }
            }
        }
        self.pending[layer] = requeue;
        let admitted = LayerRouting::new(lr.n_tokens, lr.top_k, lr.n_experts, experts);
        (admitted, carried, stats, cap)
    }
}

/// Next-ranked under-cap expert for a reroute: cyclic scan from
/// `e + 1`, skipping experts already chosen by the token (the slice
/// holds the token's current slot values; [`DROPPED`] entries never
/// match a real candidate). `None` when every distinct expert is at
/// cap. This is the O(E) reference the candidate ring replaced, kept
/// behind [`CapacityEnforcer::force_scan_reroute`] for parity gates.
fn next_ranked_scan(e: u16, cap: u32, counts: &[u32], token_slots: &[u16]) -> Option<u16> {
    let n = counts.len();
    for off in 1..n {
        let cand = (e as usize + off) % n;
        if counts[cand] < cap && !token_slots.contains(&(cand as u16)) {
            return Some(cand as u16);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::routing::RoutingModel;

    fn cfg(factor: f64, policy: CapacityPolicy) -> CapacityConfig {
        CapacityConfig { factor, policy }
    }

    fn skewed_step(seed: u64, n_tokens: usize) -> StepRouting {
        let mut m = RoutingModel::calibrated(3, 16, 4, 2, seed);
        m.route_step(&vec![0u16; n_tokens])
    }

    #[test]
    fn infinite_factor_is_bit_identical() {
        let step = skewed_step(5, 64);
        let mut enf = CapacityEnforcer::new(&cfg(f64::INFINITY, CapacityPolicy::Drop), 3, 8);
        let view = enf.enforce_step(&step);
        for (a, b) in view.routing.layers.iter().zip(&step.layers) {
            assert_eq!(a, b);
        }
        let t = view.totals();
        assert_eq!(t.offered, t.admitted);
        assert_eq!(t.dropped + t.queued + t.rerouted, 0);
        assert_eq!(enf.backlog(), 0);
    }

    #[test]
    fn drop_conserves_and_respects_cap() {
        let step = skewed_step(7, 64);
        let mut enf = CapacityEnforcer::new(&cfg(1.0, CapacityPolicy::Drop), 3, 8);
        let view = enf.enforce_step(&step);
        for (l, s) in view.layer_stats.iter().enumerate() {
            assert_eq!(s.admitted + s.dropped + s.queued, s.offered, "layer {l}");
            assert_eq!(s.queued, 0);
            let counts = view.routing.layers[l].expert_counts();
            for (e, &c) in counts.iter().enumerate() {
                assert!(c <= view.caps[l], "expert {e} over cap: {c} > {}", view.caps[l]);
            }
        }
        // skewed stream at factor 1.0 must actually bind
        assert!(view.totals().dropped > 0, "cap never bound on a skewed stream");
        let per_token: u32 = view.dropped_per_token.iter().sum();
        assert_eq!(u64::from(per_token), view.totals().dropped);
    }

    #[test]
    fn reroute_keeps_tokens_distinct_and_under_cap() {
        let step = skewed_step(9, 64);
        let mut enf = CapacityEnforcer::new(&cfg(1.0, CapacityPolicy::Reroute), 3, 8);
        let view = enf.enforce_step(&step);
        assert!(view.totals().rerouted > 0, "nothing rerouted on a skewed stream");
        for lr in &view.routing.layers {
            let counts = lr.expert_counts();
            let cap = enf.cap_for(lr.n_tokens, lr.top_k, lr.n_experts);
            assert!(counts.iter().all(|&c| c <= cap));
            for t in 0..lr.n_tokens {
                let mut s: Vec<u16> = lr
                    .token_experts(t)
                    .iter()
                    .copied()
                    .filter(|&e| e != DROPPED)
                    .collect();
                s.sort_unstable();
                s.dedup();
                assert_eq!(
                    s.len(),
                    lr.token_experts(t).iter().filter(|&&e| e != DROPPED).count(),
                    "reroute duplicated an expert within token {t}"
                );
            }
        }
    }

    #[test]
    fn queue_carries_to_next_step_fifo() {
        let step = skewed_step(11, 64);
        let mut enf = CapacityEnforcer::new(&cfg(1.0, CapacityPolicy::Queue), 3, 8);
        let v1 = enf.enforce_step(&step);
        let queued: u64 = v1.totals().queued;
        assert!(queued > 0, "nothing queued on a skewed stream");
        assert_eq!(enf.backlog() as u64, queued);
        assert_eq!(v1.totals().dropped, 0, "queue policy must not drop");
        // a uniform (unskewed) next step admits the backlog ahead of
        // fresh traffic without breaching the cap
        let mut m = RoutingModel::new(3, 16, 4, 2, 8.0, 0.0, 1.0, 3);
        let next = m.route_step(&vec![0u16; 64]);
        let v2 = enf.enforce_step(&next);
        let carried: u64 = v2.totals().carried_admitted;
        assert!(carried > 0, "backlog never admitted");
        for (l, s) in v2.layer_stats.iter().enumerate() {
            assert_eq!(s.carried_admitted + s.requeued, s.carried_in, "layer {l}");
            // caps hold with the backlog included
            let mut counts = v2.routing.layers[l].expert_counts();
            for &(e, _) in &v2.carried[l] {
                counts[e as usize] += 1;
            }
            assert!(counts.iter().all(|&c| c <= v2.caps[l]));
        }
    }

    #[test]
    fn deterministic_replay() {
        let step = skewed_step(13, 48);
        for policy in [CapacityPolicy::Drop, CapacityPolicy::Reroute, CapacityPolicy::Queue] {
            let mut a = CapacityEnforcer::new(&cfg(1.25, policy), 3, 8);
            let mut b = CapacityEnforcer::new(&cfg(1.25, policy), 3, 8);
            let va = a.enforce_step(&step);
            let vb = b.enforce_step(&step);
            assert_eq!(va.routing.layers, vb.routing.layers);
            assert_eq!(va.layer_stats, vb.layer_stats);
            assert_eq!(va.carried, vb.carried);
        }
    }

    #[test]
    fn ring_reroute_matches_scan_reference() {
        // randomized streams at several tightness levels: the ring and
        // the O(E) scan must produce bit-identical admitted routings,
        // stats, and backlogs
        for seed in [3u64, 9, 17, 29] {
            for factor in [0.5, 1.0, 1.25, 2.0] {
                let step = skewed_step(seed, 96);
                let mut ring = CapacityEnforcer::new(&cfg(factor, CapacityPolicy::Reroute), 3, 8);
                let mut scan = CapacityEnforcer::new(&cfg(factor, CapacityPolicy::Reroute), 3, 8);
                scan.force_scan_reroute();
                for round in 0..3 {
                    let vr = ring.enforce_step(&step);
                    let vs = scan.enforce_step(&step);
                    assert_eq!(
                        vr.routing.layers, vs.routing.layers,
                        "seed {seed} factor {factor} round {round}: admitted routing diverged"
                    );
                    assert_eq!(vr.layer_stats, vs.layer_stats);
                    assert_eq!(vr.carried, vs.carried);
                    assert_eq!(vr.dropped_per_token, vs.dropped_per_token);
                }
            }
        }
    }

    #[test]
    fn cap_formula_matches_snippet() {
        let enf = CapacityEnforcer::new(&cfg(1.25, CapacityPolicy::Drop), 1, 8);
        // ⌈1.25 · 4·64 / 16⌉ = ⌈20⌉ = 20
        assert_eq!(enf.cap_for(64, 4, 16), 20);
        // ⌈1.1 · 4·63 / 16⌉ = ⌈17.325⌉ = 18
        let enf = CapacityEnforcer::new(&cfg(1.1, CapacityPolicy::Drop), 1, 8);
        assert_eq!(enf.cap_for(63, 4, 16), 18);
    }
}
