//! Phase-Locked Co-Scheduling (paper §4.4): assemble the per-layer
//! dual-track timeline and account the split-phase prefetch transmission.
//!
//! Main track:  Attention → All-to-All Dispatch → MoE compute → (sync
//! wait) → All-to-All Combine.  Aux track: Predict ∥ Dispatch, Plan ∥
//! Dispatch + MoE, Prefetch ∥ MoE compute — suspended during Combine —
//! resuming into the next layer's Attention.
//!
//! Depth-L lookahead (ISSUE 2): a plan created during layer `l` targets
//! layer `l+L`, so its expert transfer may amortize over the L
//! intervening hiding windows. The [`PrefetchQueue`] carries the pending
//! transfer seconds across layer (and step) boundaries; each item has a
//! deadline — the window count until its target layer executes. An item
//! reaching its target layer may still finish during that layer's
//! Attention (the split-phase resume window); whatever remains then is
//! `exposed` and extends the critical path. With split-phase disabled
//! (ablation) end-of-layer leftovers contend with Combine and inflate it
//! instead.
//!
//! Fabric-aware accounting (ISSUE 3): queue items are routed flows over
//! [`crate::fabric::Fabric`] links; every hiding window grants each link
//! a budget and items drain greedy-by-deadline against the minimum
//! available budget along their path, so transfers sharing a slow
//! inter-node rail serialize while disjoint paths proceed in parallel.
//! Dispatch/Combine use the hierarchical All-to-All when a traffic
//! matrix is provided. On a flat (single-node) fabric all of this
//! degenerates to the exact pre-fabric scalar arithmetic.

use crate::fabric::{Fabric, Flow};
use crate::metrics::{LayerTimeline, Phase, PhaseSpan};
use crate::model::MoeModel;
use crate::perfmodel::{self, CommVolumes, TrafficMatrix};
use crate::telemetry::{Event, Recorder};
use crate::topology::HardwareProfile;

/// Per-layer scheduling inputs produced by a balancer + the perf model.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    /// Per-rank MoE compute seconds (eq. 2 summed over hosted experts).
    pub compute: Vec<f64>,
    /// Dispatch traffic volumes (token-level dedup applied).
    pub dispatch: CommVolumes,
    /// Per-pair dispatch traffic for hierarchical All-to-All accounting;
    /// `None` on flat fabrics (the scalar volume model is exact there).
    pub dispatch_matrix: Option<TrafficMatrix>,
    /// Routed prefetch flows (src → dst) behind `prefetch_slots`, used
    /// by multi-node fabrics to charge per-link budgets. Empty = derive
    /// conservative same-node flows from the slot counts.
    pub prefetch_flows: Vec<Flow>,
    /// Attention seconds for this layer (balanced across DP ranks).
    pub attn_time: f64,
    /// Expert prefetch slots per rank ENQUEUED during this layer — the
    /// fetches of the plan created here for layer `+prefetch_lookahead`.
    pub prefetch_slots: Vec<usize>,
    /// Hiding windows until the enqueued transfer's target layer runs.
    pub prefetch_lookahead: usize,
    /// Aux-track prediction cost (0 for baselines).
    pub predict_time: f64,
    /// Aux-track planning cost (0 for baselines).
    pub plan_time: f64,
    /// Reactive (non-hidden) transfer charged directly on the critical
    /// path (EPLB-style rebalancing).
    pub exposed_transfer: f64,
    /// Split-phase transmission on (PROBE) or off (ablation).
    pub split_phase: bool,
    /// Fraction of dispatch payload pre-sent to high-confidence predicted
    /// experts during the previous window (paper §6.4 future work:
    /// overlap All-to-All with routing). 0.0 = off.
    pub pre_dispatch_fraction: f64,
}

/// One pending expert transfer moving through the hiding windows, routed
/// over a set of fabric links.
#[derive(Debug, Clone)]
pub struct PrefetchItem {
    /// Flow id, monotone per [`PrefetchQueue`] — the key the flight
    /// recorder's enqueue → landed / deadline-miss lifecycle events
    /// share.
    pub id: u32,
    /// Transfer seconds still to transmit *at the flow's own line rate*
    /// (`rate`); exposure and queue pending are reported in these
    /// seconds, matching the pre-fabric scalar accounting.
    pub remaining: f64,
    /// Line rate of the flow's path (bytes/s).
    pub rate: f64,
    /// Fabric link indices the flow occupies (single index 0 on a flat
    /// fabric, where all prefetch traffic shares one `net_bw` pipe).
    pub links: Vec<u32>,
    /// Hiding windows (layers) left before the target layer executes;
    /// 0 = the target layer is the one being scheduled now.
    pub due_in: usize,
}

impl PrefetchItem {
    /// Drain this item against per-link budgets (`avail[l]` =
    /// link-seconds left in the window) and the phase's wall-clock
    /// duration `wall`: a flow transmits at its own line rate, so it can
    /// send at most `wall` seconds of line-rate time per phase even when
    /// a link aggregate (e.g. a multi-rail node) is wider than its path
    /// rate. A flow slower than a link consumes proportionally less of
    /// that link's time; on a flat fabric the factor is exactly 1.0 and
    /// `wall` equals the single link's budget, so this reduces to the
    /// scalar serial drain. Returns the seconds transmitted.
    fn drain(&mut self, avail: &mut [f64], wall: f64, fabric: &Fabric) -> f64 {
        let mut limit = self.remaining.min(wall.max(0.0));
        for &l in &self.links {
            let f = fabric.link_raw_bw(l as usize) / self.rate;
            limit = limit.min((avail[l as usize] * f).max(0.0));
        }
        let sent = limit.max(0.0);
        if sent > 0.0 {
            self.remaining -= sent;
            for &l in &self.links {
                let f = fabric.link_raw_bw(l as usize) / self.rate;
                avail[l as usize] -= sent / f;
            }
        }
        sent
    }
}

/// Pending prefetch transfers carried across layers and steps
/// (continuous lookahead pipelining). Also owns the scheduler's
/// step-reused working buffers (per-link budgets, flow grouping,
/// staged items): they are reset — never freed — each
/// [`schedule_layer_fabric`] call, so the steady-state scheduling loop
/// allocates nothing (ISSUE 6).
///
/// The queue is agnostic to HOW its flows were planned: the
/// asynchronous control pipeline (`[perf] pipeline_control`, ISSUE 10)
/// feeds the exact same [`LayerSchedule`] contract — per-layer
/// `prefetch_flows`/`prefetch_slots` plus aux-track
/// `predict_time`/`plan_time` — as synchronous planning, with every
/// plan sealed before its decision is emitted, so queue state and all
/// virtual-time timelines are bit-identical in both modes.
#[derive(Debug, Clone, Default)]
pub struct PrefetchQueue {
    items: Vec<PrefetchItem>,
    /// Per-link seconds left in the current phase window.
    avail: Vec<f64>,
    /// Plan-completion-floored budgets for items enqueued this layer.
    new_avail: Vec<f64>,
    /// (src, dst, bytes) flow-grouping scratch.
    pairs: Vec<(usize, usize, f64)>,
    /// Items enqueued this layer, before they join `items`.
    staged: Vec<PrefetchItem>,
    /// Next flow id to hand out (telemetry lifecycle key).
    next_id: u32,
}

impl PrefetchQueue {
    /// Empty queue.
    pub fn new() -> PrefetchQueue {
        PrefetchQueue::default()
    }

    /// Total transfer seconds still queued.
    pub fn pending(&self) -> f64 {
        self.items.iter().map(|i| i.remaining).sum()
    }

    /// True when no transfer is in flight.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Number of queued transfer items.
    pub fn len(&self) -> usize {
        self.items.len()
    }
}

/// Build the dual-track timeline for one MoE layer on a flat
/// (single-node) fabric — the pre-fabric scalar model. Thin wrapper over
/// [`schedule_layer_fabric`]; kept for the many single-node call sites.
pub fn schedule_layer(
    s: &LayerSchedule,
    queue: &mut PrefetchQueue,
    model: &MoeModel,
    hw: &HardwareProfile,
) -> LayerTimeline {
    let fabric = Fabric::flat(s.compute.len(), hw);
    schedule_layer_fabric(s, queue, model, hw, &fabric)
}

/// Convert this layer's enqueued fetches into routed queue items. Flat
/// fabrics aggregate to ONE item at the scalar `transfer_time` of the
/// max-slot rank (per-rank NVSwitch ports transfer in parallel; the
/// leader view tracks the slowest — exactly the pre-fabric accounting).
/// Multi-node fabrics enqueue one item per (src, dst) flow group so
/// rail contention is charged where it occurs.
fn stage_prefetch_items(
    s: &LayerSchedule,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: &Fabric,
    pairs: &mut Vec<(usize, usize, f64)>,
    out: &mut Vec<PrefetchItem>,
    next_id: &mut u32,
) {
    out.clear();
    let mut fresh_id = || {
        let id = *next_id;
        *next_id = next_id.wrapping_add(1);
        id
    };
    let due = s.prefetch_lookahead.max(1);
    let max_slots = s.prefetch_slots.iter().copied().max().unwrap_or(0);
    if fabric.is_flat() {
        let t_new = perfmodel::transfer_time(max_slots, model, hw);
        if t_new <= 0.0 {
            return;
        }
        out.push(PrefetchItem {
            id: fresh_id(),
            remaining: t_new,
            rate: fabric.intra.bw,
            links: vec![0],
            due_in: due,
        });
        return;
    }
    if !s.prefetch_flows.is_empty() {
        // group by (src, dst): one stream per pair. A stable sort plus
        // adjacent merge accumulates each pair's bytes in arrival order
        // and emits pairs in (src, dst) order — exactly the former
        // BTreeMap grouping, without its per-call node allocations.
        pairs.clear();
        pairs.extend(s.prefetch_flows.iter().map(|f| (f.src, f.dst, f.bytes)));
        pairs.sort_by_key(|&(src, dst, _)| (src, dst));
        let mut i = 0;
        while i < pairs.len() {
            let (src, dst, mut bytes) = pairs[i];
            i += 1;
            while i < pairs.len() && pairs[i].0 == src && pairs[i].1 == dst {
                bytes += pairs[i].2;
                i += 1;
            }
            if bytes <= 0.0 {
                continue;
            }
            let (rate, links) = fabric.prefetch_path(src, dst);
            // cross-node streams pay one rail rendezvous up front
            // (consistent with Fabric::transfer_time_flow)
            let base = if fabric.same_node(src, dst) {
                0.0
            } else {
                fabric.inter.base_latency
            };
            out.push(PrefetchItem {
                id: fresh_id(),
                remaining: bytes / rate + base,
                rate,
                links,
                due_in: due,
            });
        }
        return;
    }
    // no routed flows provided: conservative same-node streams per rank
    out.extend(
        s.prefetch_slots
            .iter()
            .enumerate()
            .filter(|&(_, &slots)| slots > 0)
            .map(|(r, &slots)| PrefetchItem {
                id: fresh_id(),
                remaining: perfmodel::transfer_time(slots, model, hw),
                rate: fabric.intra.bw,
                links: vec![fabric.link_rank_in(r) as u32],
                due_in: due,
            }),
    );
}

/// Build the dual-track timeline for one MoE layer, draining `queue`
/// through this layer's hiding window. Prefetch and All-to-All are
/// charged against the fabric's shared per-link budgets; a flat fabric
/// reproduces the pre-fabric single-track accounting exactly.
///
/// Thin wrapper over [`schedule_layer_fabric_rec`] with a disabled
/// flight recorder (zero allocation, zero behavior change).
pub fn schedule_layer_fabric(
    s: &LayerSchedule,
    queue: &mut PrefetchQueue,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: &Fabric,
) -> LayerTimeline {
    let mut rec = Recorder::disabled();
    schedule_layer_fabric_rec(s, queue, model, hw, fabric, &mut rec, 0, 0)
}

/// [`schedule_layer_fabric`] plus flight-recorder lifecycle events:
/// every staged transfer emits `PrefetchEnqueue`, every fully drained
/// item `PrefetchLanded`, and every transfer still pending when its
/// target layer runs `PrefetchDeadlineMiss` carrying the exposed
/// seconds. The recorder is pure observation — timeline arithmetic,
/// drain order, and queue state are bit-identical to the wrapper.
#[allow(clippy::too_many_arguments)]
pub fn schedule_layer_fabric_rec(
    s: &LayerSchedule,
    queue: &mut PrefetchQueue,
    model: &MoeModel,
    hw: &HardwareProfile,
    fabric: &Fabric,
    rec: &mut Recorder,
    step: u32,
    layer: u16,
) -> LayerTimeline {
    let ep = s.compute.len();
    let bw = hw.effective_alltoall_bw();
    // Predictive pre-dispatch (§6.4): the confident fraction of payloads
    // was already streamed during the previous window; only the residual
    // (mispredicted / low-confidence) volume is on the critical path.
    let residual = (1.0 - s.pre_dispatch_fraction).clamp(0.0, 1.0);
    let (dispatch_dur, mut combine_dur, own_disp): (f64, f64, Vec<f64>) =
        match (&s.dispatch_matrix, fabric.is_flat()) {
            (Some(m), false) => {
                // hierarchical All-to-All over the link graph
                let (own, dur) = fabric.dispatch_rank_times(&m.scaled(residual));
                let combine = fabric.alltoall_time(&m.transposed());
                (dur, combine, own)
            }
            _ => {
                // scalar bottleneck-rank model (exact on one node)
                let dispatch_vol = perfmodel::CommVolumes {
                    v_in: s.dispatch.v_in.iter().map(|v| v * residual).collect(),
                    v_out: s.dispatch.v_out.iter().map(|v| v * residual).collect(),
                };
                let dur = perfmodel::alltoall_time(&dispatch_vol, hw);
                let own = dispatch_vol
                    .critical()
                    .iter()
                    .map(|&c| hw.collective_base_latency + c / bw)
                    .collect();
                // Combine mirrors dispatch volumes with directions swapped.
                let combine_vol = CommVolumes {
                    v_in: s.dispatch.v_out.clone(),
                    v_out: s.dispatch.v_in.clone(),
                };
                (dur, perfmodel::alltoall_time(&combine_vol, hw), own)
            }
        };

    // ---- prefetch accounting (split-phase, cross-layer queue, shared
    // per-link budgets) ----
    let plan_done = s.predict_time + s.plan_time;
    let compute_max = s.compute.iter().cloned().fold(0.0, f64::max);
    let n_links = fabric.link_count();
    let mut exposed = 0.0;

    // most urgent first
    queue.items.sort_by_key(|i| i.due_in);

    // Phase A — this layer's Attention: the split-phase resume window.
    // Items whose target layer is THIS one must finish here; what they
    // miss is exposed (the expert is needed at dispatch time). Backlog
    // items may also stream. Attention-resume transmission IS the
    // split-phase mechanism, so the ablation without it gets no
    // attention window at all.
    let attn_window = if s.split_phase { s.attn_time } else { 0.0 };
    queue.avail.clear();
    queue.avail.resize(n_links, attn_window);
    let mut attn_sent = 0.0;
    for item in queue.items.iter_mut() {
        attn_sent += item.drain(&mut queue.avail, attn_window, fabric);
        if item.due_in == 0 && item.remaining > 0.0 {
            exposed += item.remaining;
            if rec.is_on() {
                rec.record(Event::PrefetchDeadlineMiss {
                    step,
                    layer,
                    flow: item.id,
                    exposed: item.remaining,
                });
            }
            item.remaining = 0.0;
        } else if rec.is_on() && item.remaining <= 1e-15 {
            rec.record(Event::PrefetchLanded {
                step,
                layer,
                flow: item.id,
            });
        }
    }
    queue.items.retain(|i| i.remaining > 1e-15);

    // Phase B — Dispatch + MoE compute: backlog transmits from the start
    // of Dispatch; the transfers enqueued THIS layer can only start once
    // their plan lands (predict + plan on the aux track).
    let cap = dispatch_dur + compute_max;
    queue.avail.clear();
    queue.avail.resize(n_links, cap);
    let mut phase_b_sent = 0.0;
    for item in queue.items.iter_mut() {
        phase_b_sent += item.drain(&mut queue.avail, cap, fabric);
        if rec.is_on() && item.remaining <= 1e-15 {
            rec.record(Event::PrefetchLanded {
                step,
                layer,
                flow: item.id,
            });
        }
    }
    let mut next_id = queue.next_id;
    stage_prefetch_items(
        s,
        model,
        hw,
        fabric,
        &mut queue.pairs,
        &mut queue.staged,
        &mut next_id,
    );
    queue.next_id = next_id;
    if rec.is_on() {
        for it in queue.staged.iter() {
            rec.record(Event::PrefetchEnqueue {
                step,
                layer,
                flow: it.id,
                bytes: it.remaining * it.rate,
                due_in: it.due_in.min(u8::MAX as usize) as u8,
            });
        }
    }
    let t_new: f64 = queue.staged.iter().map(|i| i.remaining).sum();
    // plan-completion floor: what the backlog left, capped by the time
    // remaining after predict+plan
    queue.new_avail.clear();
    queue
        .new_avail
        .extend(queue.avail.iter().map(|&a| a.min(cap - plan_done)));
    for item in queue.staged.iter_mut() {
        phase_b_sent += item.drain(&mut queue.new_avail, cap - plan_done, fabric);
        if rec.is_on() && item.remaining <= 1e-15 {
            rec.record(Event::PrefetchLanded {
                step,
                layer,
                flow: item.id,
            });
        }
    }

    // Phase C — Combine: split-phase suspends transmission. Without it
    // (ablation) there is no resume window at the target layer, so any
    // transfer due before the NEXT layer must finish during Combine,
    // contending with (and inflating) it. Items with farther deadlines
    // keep draining in later windows — depth-L amortization survives
    // the ablation.
    if !s.split_phase {
        let mut leftover = 0.0;
        for item in queue.items.iter_mut().chain(queue.staged.iter_mut()) {
            if item.due_in <= 1 {
                leftover += item.remaining;
                if rec.is_on() && item.remaining > 1e-15 {
                    // force-cleared into Combine: landed, but the cost
                    // shows up as combine inflation, not exposure
                    rec.record(Event::PrefetchLanded {
                        step,
                        layer,
                        flow: item.id,
                    });
                }
                item.remaining = 0.0;
            }
        }
        combine_dur += leftover;
    }

    // survivors carry to the next window, one deadline closer
    queue.items.retain(|i| i.remaining > 1e-15);
    for it in queue.staged.drain(..) {
        if it.remaining > 1e-15 {
            queue.items.push(it);
        }
    }
    for item in queue.items.iter_mut() {
        item.due_in = item.due_in.saturating_sub(1);
    }

    exposed += s.exposed_transfer;

    // ---- main-track spans ----
    let attn_end = s.attn_time;
    let dispatch_end = attn_end + dispatch_dur;
    let comp_end_max = dispatch_end + compute_max;
    let mut ranks = Vec::with_capacity(ep);
    for r in 0..ep {
        let mut spans = Vec::with_capacity(6);
        spans.push(PhaseSpan {
            phase: Phase::Attention,
            start: 0.0,
            end: attn_end,
        });
        // own traffic first, then wait for the collective to complete
        spans.push(PhaseSpan {
            phase: Phase::Dispatch,
            start: attn_end,
            end: attn_end + own_disp[r],
        });
        if own_disp[r] < dispatch_dur {
            spans.push(PhaseSpan {
                phase: Phase::SyncWait,
                start: attn_end + own_disp[r],
                end: dispatch_end,
            });
        }
        let comp_end = dispatch_end + s.compute[r];
        spans.push(PhaseSpan {
            phase: Phase::MoeCompute,
            start: dispatch_end,
            end: comp_end,
        });
        if comp_end < comp_end_max {
            // straggler wait: this is what inflates Combine in Fig. 11
            spans.push(PhaseSpan {
                phase: Phase::SyncWait,
                start: comp_end,
                end: comp_end_max,
            });
        }
        spans.push(PhaseSpan {
            phase: Phase::Combine,
            start: comp_end_max,
            end: comp_end_max + combine_dur,
        });
        ranks.push(spans);
    }

    // ---- aux-track spans (leader view) ----
    let mut aux = Vec::new();
    if attn_sent > 0.0 {
        // resumed / backlog transmission during Attention
        aux.push(PhaseSpan {
            phase: Phase::Prefetch,
            start: 0.0,
            end: attn_sent,
        });
    }
    if s.predict_time > 0.0 {
        aux.push(PhaseSpan {
            phase: Phase::Predict,
            start: attn_end,
            end: attn_end + s.predict_time,
        });
    }
    if s.plan_time > 0.0 {
        aux.push(PhaseSpan {
            phase: Phase::Plan,
            start: attn_end + s.predict_time,
            end: attn_end + plan_done,
        });
    }
    if phase_b_sent > 0.0 {
        // rendered from the start of the transmissible window
        aux.push(PhaseSpan {
            phase: Phase::Prefetch,
            start: attn_end,
            end: attn_end + phase_b_sent,
        });
    }
    if t_new > 0.0 || phase_b_sent > 0.0 {
        aux.push(PhaseSpan {
            phase: Phase::Update,
            start: comp_end_max + combine_dur,
            end: comp_end_max + combine_dur + hw.kernel_launch,
        });
    }

    LayerTimeline {
        ranks,
        aux,
        exposed_overhead: exposed,
    }
}

/// Per-token effective-context composition of one mixed batch (ISSUE 5):
/// groups of `(tokens, kv_rows)` where `kv_rows` is the effective KV
/// rows each token in the group reads after GQA sharing and flash tile
/// reuse. Built by [`crate::engine::BatchComposition::context_profile`]
/// from the batch's per-request context lengths, so attention is charged
/// for the *actual* context distribution instead of one global
/// `mean_ctx` scalar.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ContextProfile {
    /// `(token count, effective KV rows per token)` groups.
    pub groups: Vec<(usize, usize)>,
}

impl ContextProfile {
    /// A single-group profile: `tokens` tokens all reading `kv_rows`
    /// effective rows (the legacy scalar model).
    pub fn uniform(tokens: usize, kv_rows: usize) -> ContextProfile {
        ContextProfile {
            groups: vec![(tokens, kv_rows)],
        }
    }

    /// Append a group (no-op for empty groups).
    pub fn push(&mut self, tokens: usize, kv_rows: usize) {
        if tokens > 0 {
            self.groups.push((tokens, kv_rows));
        }
    }

    /// Tokens across all groups.
    pub fn total_tokens(&self) -> usize {
        self.groups.iter().map(|&(t, _)| t).sum()
    }

    /// Token-weighted KV rows (Σ tokens × rows) — the quantity both the
    /// score FLOPs and the KV streaming bytes scale with.
    pub fn total_kv_rows(&self) -> f64 {
        self.groups
            .iter()
            .map(|&(t, c)| t as f64 * c as f64)
            .sum()
    }
}

/// Attention time estimate for one layer at `tokens_per_rank` tokens:
/// projection FLOPs plus KV-cache streaming. `mean_ctx` is the
/// *effective* KV rows read per query token after GQA sharing and
/// flash-attention tile reuse (≈ context/8 for GQA-8 decode; far less
/// for prefill where query tiles share KV). The paper notes chunked
/// prefill + short prompts keep attention off the critical path; MoE
/// stragglers dominate. The scalar primitive behind
/// [`attention_time_profile`], kept for direct simulator call sites.
pub fn attention_time(
    tokens_per_rank: usize,
    mean_ctx: usize,
    model: &MoeModel,
    hw: &HardwareProfile,
) -> f64 {
    let h = model.hidden as f64;
    let proj_flops = 8.0 * h * h * tokens_per_rank as f64;
    let score_flops = 4.0 * mean_ctx as f64 * h * tokens_per_rank as f64;
    let flops_t = (proj_flops + score_flops) / (hw.gemm_max_eff * hw.peak_flops);
    let kv_bytes = tokens_per_rank as f64 * mean_ctx as f64 * 2.0 * h * model.dtype_bytes;
    let mem_t = kv_bytes / hw.hbm_bw;
    flops_t.max(mem_t) + hw.kernel_launch
}

/// [`attention_time`] generalized to a mixed batch's per-request context
/// distribution: the batch's tokens (and their token-weighted KV rows)
/// are spread across `ep` DP ranks. A uniform profile reproduces the
/// scalar model exactly, so the legacy decode path is a special case.
pub fn attention_time_profile(
    profile: &ContextProfile,
    ep: usize,
    model: &MoeModel,
    hw: &HardwareProfile,
) -> f64 {
    let ep = ep.max(1) as f64;
    let tokens_per_rank = (profile.total_tokens() as f64 / ep).ceil();
    let rows_per_rank = profile.total_kv_rows() / ep;
    let h = model.hidden as f64;
    let proj_flops = 8.0 * h * h * tokens_per_rank;
    let score_flops = 4.0 * h * rows_per_rank;
    let flops_t = (proj_flops + score_flops) / (hw.gemm_max_eff * hw.peak_flops);
    let kv_bytes = rows_per_rank * 2.0 * h * model.dtype_bytes;
    let mem_t = kv_bytes / hw.hbm_bw;
    flops_t.max(mem_t) + hw.kernel_launch
}

/// Predictor cost: batched MLP inference plus the lightweight All-Gather
/// of per-rank estimates (§5).
pub fn predict_time(tokens_per_rank: usize, model: &MoeModel, hw: &HardwareProfile) -> f64 {
    let h = model.hidden as f64;
    // router prior + small residual MLP ≈ 2*H*(E + H/2) MACs per token
    let flops = tokens_per_rank as f64 * 2.0 * h * (model.n_experts as f64 + h / 2.0);
    flops / (hw.gemm_max_eff * hw.peak_flops) + hw.collective_base_latency
}

/// Modeled single-SM solver cost (§5: serial iterative updates, k_max
/// capped). The rust planner's wall-clock is benchmarked separately and
/// must also fit the window (EXPERIMENTS.md §Perf).
pub fn plan_time(iterations: usize, hw: &HardwareProfile) -> f64 {
    hw.kernel_launch + iterations as f64 * 1.5e-6
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_sched(compute: Vec<f64>, slots: Vec<usize>, split: bool) -> LayerSchedule {
        let ep = compute.len();
        LayerSchedule {
            compute,
            dispatch: CommVolumes {
                v_in: vec![1e6; ep],
                v_out: vec![1e6; ep],
            },
            dispatch_matrix: None,
            prefetch_flows: Vec::new(),
            attn_time: 100e-6,
            prefetch_slots: slots,
            prefetch_lookahead: 1,
            predict_time: 5e-6,
            plan_time: 20e-6,
            exposed_transfer: 0.0,
            split_phase: split,
            pre_dispatch_fraction: 0.0,
        }
    }

    fn hw() -> HardwareProfile {
        HardwareProfile::hopper_141()
    }
    fn model() -> MoeModel {
        MoeModel::gpt_oss_120b()
    }

    fn one(s: &LayerSchedule) -> LayerTimeline {
        let mut q = PrefetchQueue::new();
        schedule_layer(s, &mut q, &model(), &hw())
    }

    #[test]
    fn pre_dispatch_shrinks_dispatch_phase() {
        let mut s = mk_sched(vec![1e-3; 8], vec![0; 8], true);
        let base = one(&s);
        s.pre_dispatch_fraction = 0.9;
        let pre = one(&s);
        assert!(
            pre.mean_phase_dur(Phase::Dispatch) < base.mean_phase_dur(Phase::Dispatch),
            "pre-dispatch did not shrink dispatch"
        );
    }

    #[test]
    fn straggler_creates_sync_wait() {
        let tl = one(&mk_sched(vec![1e-3, 0.2e-3], vec![0, 0], true));
        assert!(tl.phase_dur(1, Phase::SyncWait) > 0.5e-3);
        assert!(tl.phase_dur(0, Phase::SyncWait) < tl.phase_dur(1, Phase::SyncWait));
    }

    #[test]
    fn small_prefetch_fully_hidden() {
        // 1 expert ≈ 47.5MB / 450GB/s ≈ 105µs < compute window (1ms)
        let mut q = PrefetchQueue::new();
        let tl = schedule_layer(
            &mk_sched(vec![1e-3; 8], vec![1; 8], true),
            &mut q,
            &model(),
            &hw(),
        );
        assert_eq!(tl.exposed_overhead, 0.0);
        assert!(q.is_empty(), "transfer should finish inside the window");
        assert!(tl.aux.iter().any(|s| s.phase == Phase::Prefetch));
    }

    #[test]
    fn oversized_prefetch_exposes_at_target_layer() {
        // tiny compute window, many slots → the transfer cannot finish
        // before its target layer (the next one) and is exposed THERE
        let mut s = mk_sched(vec![10e-6; 8], vec![3; 8], true);
        s.attn_time = 10e-6;
        let mut q = PrefetchQueue::new();
        let first = schedule_layer(&s, &mut q, &model(), &hw());
        assert_eq!(first.exposed_overhead, 0.0, "no deadline yet");
        assert!(!q.is_empty(), "leftover must carry to the next window");
        let mut s2 = mk_sched(vec![10e-6; 8], vec![0; 8], true);
        s2.attn_time = 10e-6;
        let second = schedule_layer(&s2, &mut q, &model(), &hw());
        assert!(second.exposed_overhead > 0.0, "missed deadline not exposed");
        assert!(q.is_empty());
    }

    #[test]
    fn recorder_sees_full_prefetch_lifecycle() {
        use crate::config::TelemetryConfig;
        let on = TelemetryConfig {
            enabled: true,
            ring_capacity: 64,
            sample_every: 1,
        };
        let fabric = Fabric::flat(8, &hw());

        // hidden transfer: enqueue then landed, no miss
        let mut rec = Recorder::new(&on);
        let mut q = PrefetchQueue::new();
        let s = mk_sched(vec![1e-3; 8], vec![1; 8], true);
        schedule_layer_fabric_rec(&s, &mut q, &model(), &hw(), &fabric, &mut rec, 7, 3);
        let kinds: Vec<&str> = rec.events().map(|(_, e)| e.kind()).collect();
        assert!(kinds.contains(&"prefetch_enqueue"), "{kinds:?}");
        assert!(kinds.contains(&"prefetch_landed"), "{kinds:?}");
        assert!(!kinds.contains(&"prefetch_deadline_miss"), "{kinds:?}");
        // enqueue and landed share the flow id
        let enq_flow = rec
            .events()
            .find_map(|(_, e)| match *e {
                Event::PrefetchEnqueue { flow, step, layer, .. } => {
                    assert_eq!((step, layer), (7, 3));
                    Some(flow)
                }
                _ => None,
            })
            .unwrap();
        assert!(rec.events().any(|(_, e)| matches!(
            *e,
            Event::PrefetchLanded { flow, .. } if flow == enq_flow
        )));

        // oversized transfer: the miss at the target layer carries the
        // exposed seconds the timeline charges
        let mut rec = Recorder::new(&on);
        let mut q = PrefetchQueue::new();
        let mut s = mk_sched(vec![10e-6; 8], vec![3; 8], true);
        s.attn_time = 10e-6;
        schedule_layer_fabric_rec(&s, &mut q, &model(), &hw(), &fabric, &mut rec, 0, 0);
        let mut s2 = mk_sched(vec![10e-6; 8], vec![0; 8], true);
        s2.attn_time = 10e-6;
        let second =
            schedule_layer_fabric_rec(&s2, &mut q, &model(), &hw(), &fabric, &mut rec, 0, 1);
        assert!(second.exposed_overhead > 0.0);
        let missed: Vec<f64> = rec
            .events()
            .filter_map(|(_, e)| match *e {
                Event::PrefetchDeadlineMiss { exposed, .. } => Some(exposed),
                _ => None,
            })
            .collect();
        assert!(!missed.is_empty(), "miss not recorded");
        let total: f64 = missed.iter().sum();
        assert!(
            (total - second.exposed_overhead).abs() < 1e-12,
            "event exposure {total} != timeline exposure {}",
            second.exposed_overhead
        );
        assert_eq!(rec.registry.prefetch_deadline_missed_total, missed.len() as u64);
        assert!(rec.registry.exposed_seconds_total > 0.0);

        // recording changed nothing: a disabled-recorder replay of the
        // same schedule is bit-identical
        let mut q2 = PrefetchQueue::new();
        let a = schedule_layer_fabric(&s, &mut q2, &model(), &hw(), &fabric);
        let b = schedule_layer_fabric(&s2, &mut q2, &model(), &hw(), &fabric);
        let mut q3 = PrefetchQueue::new();
        let mut rec3 = Recorder::new(&on);
        let a2 = schedule_layer_fabric_rec(&s, &mut q3, &model(), &hw(), &fabric, &mut rec3, 0, 0);
        let b2 = schedule_layer_fabric_rec(&s2, &mut q3, &model(), &hw(), &fabric, &mut rec3, 0, 1);
        assert_eq!(a.exposed_overhead.to_bits(), a2.exposed_overhead.to_bits());
        assert_eq!(b.exposed_overhead.to_bits(), b2.exposed_overhead.to_bits());
        assert_eq!(a.makespan().to_bits(), a2.makespan().to_bits());
        assert_eq!(b.makespan().to_bits(), b2.makespan().to_bits());
    }

    #[test]
    fn deeper_lookahead_never_increases_exposure() {
        // identical transfer demand under tight windows: more hiding
        // windows before the deadline can only reduce exposure
        let layers = 8usize;
        let mut exposures = Vec::new();
        for lookahead in [1usize, 2, 4] {
            let mut q = PrefetchQueue::new();
            let mut total = 0.0;
            for l in 0..layers {
                let slots = if l % 2 == 0 { vec![3; 8] } else { vec![0; 8] };
                let mut s = mk_sched(vec![20e-6; 8], slots, true);
                s.attn_time = 10e-6;
                s.prefetch_lookahead = lookahead;
                let tl = schedule_layer(&s, &mut q, &model(), &hw());
                total += tl.exposed_overhead;
            }
            // drain the queue so deeper depths can't defer exposure past
            // the measurement horizon (deadlines beyond `layers`)
            let mut guard = 0;
            while !q.is_empty() && guard < 16 {
                let mut s = mk_sched(vec![20e-6; 8], vec![0; 8], true);
                s.attn_time = 10e-6;
                total += schedule_layer(&s, &mut q, &model(), &hw()).exposed_overhead;
                guard += 1;
            }
            assert!(q.is_empty(), "queue failed to drain");
            exposures.push(total);
        }
        assert!(
            exposures[1] <= exposures[0] + 1e-12 && exposures[2] <= exposures[1] + 1e-12,
            "exposure increased with depth: {exposures:?}"
        );
        assert!(exposures[0] > 0.0, "test not binding: no exposure at L=1");
    }

    #[test]
    fn no_split_phase_inflates_combine() {
        let mut s = mk_sched(vec![50e-6; 8], vec![3; 8], true);
        s.attn_time = 10e-6;
        let with_split = one(&s);
        s.split_phase = false;
        let without = one(&s);
        let combine_with = with_split.mean_phase_dur(Phase::Combine);
        let combine_without = without.mean_phase_dur(Phase::Combine);
        assert!(
            combine_without > combine_with * 1.2,
            "combine {combine_with} vs {combine_without}"
        );
    }

    #[test]
    fn aux_track_hidden_when_window_ample() {
        let tl = one(&mk_sched(vec![2e-3; 8], vec![2; 8], true));
        // makespan must equal the main-track phases only
        let main: f64 = tl.ranks[0].iter().map(|s| s.dur()).sum();
        assert!((tl.makespan() - main).abs() < 1e-9);
    }

    #[test]
    fn queue_carries_across_layers_and_drains() {
        // a 3-slot transfer with a 3-window deadline drains over several
        // small windows without ever being exposed
        let mut q = PrefetchQueue::new();
        let t_total = perfmodel::transfer_time(3, &model(), &hw());
        let mut s = mk_sched(vec![100e-6; 8], vec![3; 8], true);
        s.attn_time = 20e-6;
        s.prefetch_lookahead = 3;
        let mut exposed = 0.0;
        let tl = schedule_layer(&s, &mut q, &model(), &hw());
        exposed += tl.exposed_overhead;
        let after_first = q.pending();
        assert!(after_first > 0.0 && after_first < t_total);
        for _ in 0..3 {
            let mut s2 = mk_sched(vec![100e-6; 8], vec![0; 8], true);
            s2.attn_time = 20e-6;
            exposed += schedule_layer(&s2, &mut q, &model(), &hw()).exposed_overhead;
        }
        assert!(q.is_empty(), "queue did not drain: {}", q.pending());
        assert_eq!(exposed, 0.0, "amortized transfer must stay hidden");
    }

    #[test]
    fn flat_fabric_schedule_is_identity() {
        // schedule_layer (scalar wrapper) and schedule_layer_fabric on an
        // explicit flat fabric must produce identical timelines and queue
        // state — the flat fabric IS the pre-fabric model
        let s = mk_sched(vec![40e-6; 8], vec![2; 8], true);
        let fabric = Fabric::flat(8, &hw());
        let mut q1 = PrefetchQueue::new();
        let mut q2 = PrefetchQueue::new();
        for _ in 0..4 {
            let a = schedule_layer(&s, &mut q1, &model(), &hw());
            let b = schedule_layer_fabric(&s, &mut q2, &model(), &hw(), &fabric);
            assert_eq!(a.exposed_overhead, b.exposed_overhead);
            assert_eq!(a.makespan(), b.makespan());
            assert_eq!(q1.pending(), q2.pending());
        }
    }

    #[test]
    fn cross_node_flows_drain_slower_than_intra() {
        // identical byte demand; the cross-node flow rides a 1/8 rail and
        // misses the deadline the intra-node flow meets
        let h = hw();
        let m = model();
        let fabric = crate::fabric::Fabric::multi_node_ratio(16, 2, &h, 0.125, 2);
        let run = |src: usize| -> f64 {
            let mut q = PrefetchQueue::new();
            let mut s = mk_sched(vec![150e-6; 16], vec![0; 16], true);
            s.prefetch_slots[2] = 1;
            s.prefetch_flows = vec![Flow {
                src,
                dst: 2,
                bytes: m.expert_param_bytes(),
            }];
            s.attn_time = 20e-6;
            let mut exposed =
                schedule_layer_fabric(&s, &mut q, &m, &h, &fabric).exposed_overhead;
            let s2 = mk_sched(vec![150e-6; 16], vec![0; 16], true);
            exposed += schedule_layer_fabric(&s2, &mut q, &m, &h, &fabric).exposed_overhead;
            exposed
        };
        let intra = run(5); // same node as rank 2
        let cross = run(12); // other node
        assert_eq!(intra, 0.0, "intra-node fetch must hide");
        assert!(cross > 0.0, "rail-limited fetch must miss the window");
    }

    #[test]
    fn shared_rail_budget_is_not_double_counted() {
        // two cross-node flows into different dst ports share the node
        // ingress rail: together they need twice the wall time of one
        let h = hw();
        let m = model();
        let fabric = crate::fabric::Fabric::multi_node_ratio(16, 2, &h, 0.25, 1);
        let drain_windows = |flows: Vec<Flow>| -> usize {
            let mut q = PrefetchQueue::new();
            let mut s = mk_sched(vec![100e-6; 16], vec![0; 16], true);
            s.prefetch_slots[8] = 1;
            s.prefetch_flows = flows;
            s.prefetch_lookahead = 8; // generous deadline: count windows
            s.attn_time = 0.0;
            s.predict_time = 0.0;
            s.plan_time = 0.0;
            let _ = schedule_layer_fabric(&s, &mut q, &m, &h, &fabric);
            let mut windows = 0usize;
            while !q.is_empty() && windows < 32 {
                let s2 = mk_sched(vec![100e-6; 16], vec![0; 16], true);
                let _ = schedule_layer_fabric(&s2, &mut q, &m, &h, &fabric);
                windows += 1;
            }
            windows
        };
        let b = m.expert_param_bytes();
        let one = drain_windows(vec![Flow { src: 0, dst: 8, bytes: b }]);
        let two = drain_windows(vec![
            Flow { src: 0, dst: 8, bytes: b },
            Flow { src: 1, dst: 9, bytes: b },
        ]);
        assert!(two > one, "shared rail must serialize: {one} vs {two} windows");
    }

    #[test]
    fn single_cross_flow_capped_at_its_own_line_rate() {
        // rails=2: the node aggregate is twice the flow's one-rail line
        // rate, but a single flow rides one rail — per window it can
        // send at most the window's wall time, not aggregate/rate times
        // more
        let h = hw();
        let m = model();
        let fabric = crate::fabric::Fabric::multi_node_ratio(16, 2, &h, 0.25, 2);
        let mut q = PrefetchQueue::new();
        let mut s = mk_sched(vec![100e-6; 16], vec![0; 16], true);
        s.prefetch_slots[8] = 1;
        s.prefetch_flows = vec![Flow {
            src: 0,
            dst: 8,
            bytes: m.expert_param_bytes(),
        }];
        s.prefetch_lookahead = 8;
        s.attn_time = 0.0;
        s.predict_time = 0.0;
        s.plan_time = 0.0;
        let _ = schedule_layer_fabric(&s, &mut q, &m, &h, &fabric);
        let t_total = m.expert_param_bytes() / fabric.path_rate(0, 8);
        // window wall ≈ dispatch (~15µs) + compute (100µs) < 120µs
        assert!(
            q.pending() >= t_total - 120e-6,
            "flow drained faster than its line rate: pending {} of {}",
            q.pending(),
            t_total
        );
    }

    #[test]
    fn attention_time_scales_with_tokens() {
        let m = model();
        let h = hw();
        assert!(attention_time(2048, 512, &m, &h) > attention_time(256, 512, &m, &h));
    }

    #[test]
    fn uniform_profile_matches_scalar_attention() {
        let m = model();
        let h = hw();
        for (tpr, ctx, ep) in [(768usize, 64usize, 8usize), (2048, 192, 8), (13, 7, 4)] {
            let scalar = attention_time(tpr, ctx, &m, &h);
            let profile = ContextProfile::uniform(tpr * ep, ctx);
            let mixed = attention_time_profile(&profile, ep, &m, &h);
            assert!(
                (scalar - mixed).abs() / scalar < 1e-12,
                "tpr {tpr} ctx {ctx}: {scalar} vs {mixed}"
            );
        }
    }

    #[test]
    fn longer_contexts_cost_more_attention() {
        let m = model();
        let h = hw();
        let short = ContextProfile::uniform(1024, 8);
        let mut long = ContextProfile::uniform(512, 8);
        long.push(512, 4096);
        assert_eq!(short.total_tokens(), long.total_tokens());
        assert!(
            attention_time_profile(&long, 8, &m, &h)
                > attention_time_profile(&short, 8, &m, &h)
        );
        // group accounting
        assert_eq!(long.groups.len(), 2);
        assert!((long.total_kv_rows() - (512.0 * 8.0 + 512.0 * 4096.0)).abs() < 1e-9);
    }

    #[test]
    fn control_costs_are_micro() {
        let m = model();
        let h = hw();
        assert!(predict_time(768, &m, &h) < 50e-6);
        assert!(plan_time(16, &h) < 50e-6);
    }
}
