//! Phase-Locked Co-Scheduling (paper §4.4): assemble the per-layer
//! dual-track timeline and account the split-phase prefetch transmission.
//!
//! Main track:  Attention → All-to-All Dispatch → MoE compute → (sync
//! wait) → All-to-All Combine.  Aux track: Predict ∥ Dispatch, Plan ∥
//! Dispatch + MoE, Prefetch ∥ MoE compute — suspended during Combine —
//! resuming into the next layer's Attention. Overhead not hidden inside
//! that window is `exposed` and extends the critical path; with
//! split-phase disabled (ablation) leftover prefetch bytes contend with
//! Combine and inflate it instead.

use crate::metrics::{LayerTimeline, Phase, PhaseSpan};
use crate::model::MoeModel;
use crate::perfmodel::{self, CommVolumes};
use crate::topology::HardwareProfile;

/// Per-layer scheduling inputs produced by a balancer + the perf model.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    /// Per-rank MoE compute seconds (eq. 2 summed over hosted experts).
    pub compute: Vec<f64>,
    /// Dispatch traffic volumes (token-level dedup applied).
    pub dispatch: CommVolumes,
    /// Attention seconds for this layer (balanced across DP ranks).
    pub attn_time: f64,
    /// Attention seconds of the *next* layer (tail of the hiding window).
    pub next_attn_time: f64,
    /// Expert prefetch slots per rank planned for the next layer.
    pub prefetch_slots: Vec<usize>,
    /// Aux-track control costs (0 for baselines).
    pub predict_time: f64,
    pub plan_time: f64,
    /// Reactive (non-hidden) transfer charged directly on the critical
    /// path (EPLB-style rebalancing).
    pub exposed_transfer: f64,
    /// Split-phase transmission on (PROBE) or off (ablation).
    pub split_phase: bool,
    /// Fraction of dispatch payload pre-sent to high-confidence predicted
    /// experts during the previous window (paper §6.4 future work:
    /// overlap All-to-All with routing). 0.0 = off.
    pub pre_dispatch_fraction: f64,
}

/// Build the dual-track timeline for one MoE layer.
pub fn schedule_layer(
    s: &LayerSchedule,
    model: &MoeModel,
    hw: &HardwareProfile,
) -> LayerTimeline {
    let ep = s.compute.len();
    let bw = hw.effective_alltoall_bw();
    // Predictive pre-dispatch (§6.4): the confident fraction of payloads
    // was already streamed during the previous window; only the residual
    // (mispredicted / low-confidence) volume is on the critical path.
    let residual = (1.0 - s.pre_dispatch_fraction).clamp(0.0, 1.0);
    let dispatch_vol = perfmodel::CommVolumes {
        v_in: s.dispatch.v_in.iter().map(|v| v * residual).collect(),
        v_out: s.dispatch.v_out.iter().map(|v| v * residual).collect(),
    };
    let dispatch_dur = perfmodel::alltoall_time(&dispatch_vol, hw);
    let crit = dispatch_vol.critical();

    // Combine mirrors dispatch volumes with directions swapped.
    let combine_vol = CommVolumes {
        v_in: s.dispatch.v_out.clone(),
        v_out: s.dispatch.v_in.clone(),
    };
    let mut combine_dur = perfmodel::alltoall_time(&combine_vol, hw);

    // ---- prefetch accounting (split-phase transmission) ----
    let max_slots = s.prefetch_slots.iter().copied().max().unwrap_or(0);
    let t_trans = perfmodel::transfer_time(max_slots, model, hw);
    let compute_max = s.compute.iter().cloned().fold(0.0, f64::max);
    // phase 1 window: the planner finishes during dispatch+compute; the
    // transfer may start once the plan lands, overlapping MoE compute.
    let plan_done = s.predict_time + s.plan_time;
    let phase1_window = (dispatch_dur + compute_max - plan_done).max(0.0);
    let phase1_sent = t_trans.min(phase1_window);
    let leftover = t_trans - phase1_sent;
    let mut exposed = 0.0;
    if leftover > 0.0 {
        if s.split_phase {
            // suspend during combine; resume into next attention
            let phase2 = leftover.min(s.next_attn_time);
            exposed = leftover - phase2;
        } else {
            // contend with combine for fabric bandwidth: serialized share
            combine_dur += leftover;
        }
    }
    exposed += s.exposed_transfer;

    // ---- main-track spans ----
    let attn_end = s.attn_time;
    let dispatch_end = attn_end + dispatch_dur;
    let comp_end_max = dispatch_end + compute_max;
    let mut ranks = Vec::with_capacity(ep);
    for r in 0..ep {
        let mut spans = Vec::with_capacity(6);
        spans.push(PhaseSpan {
            phase: Phase::Attention,
            start: 0.0,
            end: attn_end,
        });
        // own traffic first, then wait for the collective to complete
        let own_disp = hw.collective_base_latency + crit[r] / bw;
        spans.push(PhaseSpan {
            phase: Phase::Dispatch,
            start: attn_end,
            end: attn_end + own_disp,
        });
        if own_disp < dispatch_dur {
            spans.push(PhaseSpan {
                phase: Phase::SyncWait,
                start: attn_end + own_disp,
                end: dispatch_end,
            });
        }
        let comp_end = dispatch_end + s.compute[r];
        spans.push(PhaseSpan {
            phase: Phase::MoeCompute,
            start: dispatch_end,
            end: comp_end,
        });
        if comp_end < comp_end_max {
            // straggler wait: this is what inflates Combine in Fig. 11
            spans.push(PhaseSpan {
                phase: Phase::SyncWait,
                start: comp_end,
                end: comp_end_max,
            });
        }
        spans.push(PhaseSpan {
            phase: Phase::Combine,
            start: comp_end_max,
            end: comp_end_max + combine_dur,
        });
        ranks.push(spans);
    }

    // ---- aux-track spans (leader view) ----
    let mut aux = Vec::new();
    if s.predict_time > 0.0 {
        aux.push(PhaseSpan {
            phase: Phase::Predict,
            start: attn_end,
            end: attn_end + s.predict_time,
        });
    }
    if s.plan_time > 0.0 {
        aux.push(PhaseSpan {
            phase: Phase::Plan,
            start: attn_end + s.predict_time,
            end: attn_end + plan_done,
        });
    }
    if t_trans > 0.0 {
        let p1_start = attn_end + plan_done;
        aux.push(PhaseSpan {
            phase: Phase::Prefetch,
            start: p1_start,
            end: p1_start + phase1_sent,
        });
        if leftover > 0.0 && s.split_phase {
            // resumed segment rendered after combine
            let resume = comp_end_max + combine_dur;
            aux.push(PhaseSpan {
                phase: Phase::Prefetch,
                start: resume,
                end: resume + leftover,
            });
        }
        aux.push(PhaseSpan {
            phase: Phase::Update,
            start: comp_end_max + combine_dur,
            end: comp_end_max + combine_dur + hw.kernel_launch,
        });
    }

    LayerTimeline {
        ranks,
        aux,
        exposed_overhead: exposed,
    }
}

/// Attention time estimate for one layer at `tokens_per_rank` tokens:
/// projection FLOPs plus KV-cache streaming. `mean_ctx` is the
/// *effective* KV rows read per query token after GQA sharing and
/// flash-attention tile reuse (≈ context/8 for GQA-8 decode; far less
/// for prefill where query tiles share KV). The paper notes chunked
/// prefill + short prompts keep attention off the critical path; MoE
/// stragglers dominate.
pub fn attention_time(
    tokens_per_rank: usize,
    mean_ctx: usize,
    model: &MoeModel,
    hw: &HardwareProfile,
) -> f64 {
    let h = model.hidden as f64;
    let proj_flops = 8.0 * h * h * tokens_per_rank as f64;
    let score_flops = 4.0 * mean_ctx as f64 * h * tokens_per_rank as f64;
    let flops_t = (proj_flops + score_flops) / (hw.gemm_max_eff * hw.peak_flops);
    let kv_bytes = tokens_per_rank as f64 * mean_ctx as f64 * 2.0 * h * model.dtype_bytes;
    let mem_t = kv_bytes / hw.hbm_bw;
    flops_t.max(mem_t) + hw.kernel_launch
}

/// Predictor cost: batched MLP inference plus the lightweight All-Gather
/// of per-rank estimates (§5).
pub fn predict_time(tokens_per_rank: usize, model: &MoeModel, hw: &HardwareProfile) -> f64 {
    let h = model.hidden as f64;
    // router prior + small residual MLP ≈ 2*H*(E + H/2) MACs per token
    let flops = tokens_per_rank as f64 * 2.0 * h * (model.n_experts as f64 + h / 2.0);
    flops / (hw.gemm_max_eff * hw.peak_flops) + hw.collective_base_latency
}

/// Modeled single-SM solver cost (§5: serial iterative updates, k_max
/// capped). The rust planner's wall-clock is benchmarked separately and
/// must also fit the window (EXPERIMENTS.md §Perf).
pub fn plan_time(iterations: usize, hw: &HardwareProfile) -> f64 {
    hw.kernel_launch + iterations as f64 * 1.5e-6
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_sched(compute: Vec<f64>, slots: Vec<usize>, split: bool) -> LayerSchedule {
        let ep = compute.len();
        LayerSchedule {
            compute,
            dispatch: CommVolumes {
                v_in: vec![1e6; ep],
                v_out: vec![1e6; ep],
            },
            attn_time: 100e-6,
            next_attn_time: 100e-6,
            prefetch_slots: slots,
            predict_time: 5e-6,
            plan_time: 20e-6,
            exposed_transfer: 0.0,
            split_phase: split,
            pre_dispatch_fraction: 0.0,
        }
    }

    #[test]
    fn pre_dispatch_shrinks_dispatch_phase() {
        let mut s = mk_sched(vec![1e-3; 8], vec![0; 8], true);
        let base = schedule_layer(&s, &model(), &hw());
        s.pre_dispatch_fraction = 0.9;
        let pre = schedule_layer(&s, &model(), &hw());
        assert!(
            pre.mean_phase_dur(Phase::Dispatch) < base.mean_phase_dur(Phase::Dispatch),
            "pre-dispatch did not shrink dispatch"
        );
    }

    fn hw() -> HardwareProfile {
        HardwareProfile::hopper_141()
    }
    fn model() -> MoeModel {
        MoeModel::gpt_oss_120b()
    }

    #[test]
    fn straggler_creates_sync_wait() {
        let tl = schedule_layer(&mk_sched(vec![1e-3, 0.2e-3], vec![0, 0], true), &model(), &hw());
        assert!(tl.phase_dur(1, Phase::SyncWait) > 0.5e-3);
        assert!(tl.phase_dur(0, Phase::SyncWait) < tl.phase_dur(1, Phase::SyncWait));
    }

    #[test]
    fn small_prefetch_fully_hidden() {
        // 1 expert ≈ 47.5MB / 450GB/s ≈ 105µs < compute window (1ms)
        let tl = schedule_layer(&mk_sched(vec![1e-3; 8], vec![1; 8], true), &model(), &hw());
        assert_eq!(tl.exposed_overhead, 0.0);
        assert!(tl.aux.iter().any(|s| s.phase == Phase::Prefetch));
    }

    #[test]
    fn oversized_prefetch_exposes_overhead() {
        // tiny compute window, many slots → can't hide everything
        let mut s = mk_sched(vec![10e-6; 8], vec![3; 8], true);
        s.attn_time = 10e-6;
        s.next_attn_time = 10e-6;
        let tl = schedule_layer(&s, &model(), &hw());
        assert!(tl.exposed_overhead > 0.0);
    }

    #[test]
    fn no_split_phase_inflates_combine() {
        let mut s = mk_sched(vec![50e-6; 8], vec![3; 8], true);
        s.attn_time = 10e-6;
        s.next_attn_time = 10e-6;
        let with_split = schedule_layer(&s, &model(), &hw());
        s.split_phase = false;
        let without = schedule_layer(&s, &model(), &hw());
        let combine_with = with_split.mean_phase_dur(Phase::Combine);
        let combine_without = without.mean_phase_dur(Phase::Combine);
        assert!(
            combine_without > combine_with * 1.2,
            "combine {combine_with} vs {combine_without}"
        );
    }

    #[test]
    fn aux_track_hidden_when_window_ample() {
        let tl = schedule_layer(&mk_sched(vec![2e-3; 8], vec![2; 8], true), &model(), &hw());
        // makespan must equal the main-track phases only
        let main: f64 = tl.ranks[0].iter().map(|s| s.dur()).sum();
        assert!((tl.makespan() - main).abs() < 1e-9);
    }

    #[test]
    fn attention_time_scales_with_tokens() {
        let m = model();
        let h = hw();
        assert!(attention_time(2048, 512, &m, &h) > attention_time(256, 512, &m, &h));
    }

    #[test]
    fn control_costs_are_micro() {
        let m = model();
        let h = hw();
        assert!(predict_time(768, &m, &h) < 50e-6);
        assert!(plan_time(16, &h) < 50e-6);
    }
}
