//! Scenario engine tour: script a workload-volatility timeline, record
//! it to a JSONL trace, replay it bit-exactly, and compare balancers on
//! the identical stream.
//!
//! Run: `cargo run --release --example scenarios`

use probe::config::{BalancerKind, Config};
use probe::coordinator::Coordinator;
use probe::experiments::make_balancer;
use probe::metrics::HotspotTracker;
use probe::workload::{trace, Request, Scenario, ScenarioGenerator};

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.model.n_layers = 6; // representative layers (DESIGN.md)
    cfg.batch_per_rank = 2; // 16 decode slots: queueing stays visible
    cfg.prefill_chunk_per_rank = 1024;
    cfg
}

/// Serve one stream under one balancer; report (throughput, ttft p99,
/// exposed ms, hotspot-migration rate).
fn serve(kind: BalancerKind, reqs: &[Request]) -> (f64, f64, f64, f64) {
    let cfg = small_cfg();
    let bal = make_balancer(kind, &cfg, 42);
    let mut c = Coordinator::new(cfg, bal, 42);
    c.submit_all(reqs.iter().cloned());
    let mut hot = HotspotTracker::new(10);
    let mut exposed = 0.0;
    while let Some(out) = c.decode_step() {
        exposed += out.total_exposed();
        hot.push_loads(&out.rank_token_loads);
    }
    (
        c.metrics.throughput(),
        c.metrics.ttft_summary().p99,
        exposed * 1e3,
        hot.migration_rate(),
    )
}

fn main() {
    // 1. Script a storm: Code traffic that flips Code→Chinese→Repeat
    //    repeatedly — the adversarial regime for history-based
    //    balancers (hotspots migrate before statistics catch up).
    let mut scenario = Scenario::preset("storm", 120.0, 2.0, 4).unwrap();
    for t in &mut scenario.tenants {
        t.spec.mean_prompt_len = 16;
        t.spec.mean_new_tokens = 32;
    }
    let reqs = ScenarioGenerator::new(scenario, 7).generate();
    println!("storm scenario: {} requests over 2.0s horizon", reqs.len());

    // 2. Record it — the trace is a shareable, diffable artifact...
    let path = std::env::temp_dir().join("probe_storm.jsonl");
    let path = path.to_str().unwrap().to_string();
    trace::write_trace(&path, &reqs).unwrap();
    // ...and replays bit-exactly.
    let replayed = trace::read_trace(&path).unwrap();
    assert_eq!(replayed, reqs, "trace must round-trip bit-exactly");
    println!("recorded + replayed bit-exactly: {path}\n");

    // 3. Every balancer sees the identical stream.
    println!(
        "{:<10} {:>10} {:>12} {:>11} {:>9}",
        "system", "tok/s", "ttft p99 ms", "exposed ms", "hot-mig"
    );
    for kind in [BalancerKind::StaticEp, BalancerKind::Eplb, BalancerKind::Probe] {
        let (thr, ttft_p99, exposed, mig) = serve(kind, &replayed);
        println!(
            "{:<10} {:>10.0} {:>12.2} {:>11.3} {:>9.2}",
            kind.name(),
            thr,
            ttft_p99 * 1e3,
            exposed,
            mig
        );
    }
    println!("\nhot-mig = per-window hotspot-migration rate (storms keep it");
    println!("high; PROBE's lookahead tracks it, EPLB's history lags it).");
    println!("Full sweep: `probe bench volatility` -> bench_results/BENCH_volatility.json");
    let _ = std::fs::remove_file(&path);
}
