//! Robustness scenario (paper Fig. 9): decode traffic switches from
//! *Code* to *Chinese* mid-run; compare how static EP, DeepSeek-EPLB and
//! PROBE ride through the shift.
//!
//! Run: `cargo run --release --example semantic_shift`

use probe::config::BalancerKind;
use probe::experiments::fig9_shift::{trace, Fig9Params};

fn main() {
    let p = Fig9Params {
        steps: 300,
        shift_at: 150,
        batch_per_rank: 512,
        seed: 29,
        window: 20,
    };
    println!("GPT-OSS, ep=8: Code -> Chinese shift at step {}\n", p.shift_at);
    let st = trace(BalancerKind::StaticEp, &p);
    let ep = trace(BalancerKind::Eplb, &p);
    let pr = trace(BalancerKind::Probe, &p);
    println!("{:>6} {:>12} {:>12} {:>12}", "step", "sglang", "eplb", "probe");
    let n = st.len().min(ep.len()).min(pr.len());
    for i in 0..n {
        let marker = if (i + 1) * p.window > p.shift_at && i * p.window <= p.shift_at {
            "  <-- shift"
        } else {
            ""
        };
        println!(
            "{:>6} {:>10.0}/s {:>10.0}/s {:>10.0}/s{}",
            (i + 1) * p.window,
            st[i],
            ep[i],
            pr[i],
            marker
        );
    }
    let late = n * 3 / 4;
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\npost-shift mean: sglang {:.0}/s, eplb {:.0}/s, probe {:.0}/s",
        mean(&st[late..n]),
        mean(&ep[late..n]),
        mean(&pr[late..n])
    );
    println!("PROBE needs no warm-up and keeps throughput across the shift.");
}
