//! Capacity planning: sweep hardware profiles and replica budgets to pick
//! a deployment point — the hardware-aware side of PROBE's planner
//! (paper §2.3: compute-rich nodes shrink the hiding window; bandwidth
//! changes how many experts fit in it).
//!
//! Run: `cargo run --release --example capacity_planning`

use probe::balancers::{decide_step, Probe};
use probe::config::{Config, ProbeConfig};
use probe::perfmodel::transfer_time;
use probe::routing::RoutingModel;
use probe::simulator::ClusterSim;
use probe::topology::{Cluster, HardwareProfile};
use probe::util::stats::mean;

fn main() {
    println!("PROBE capacity planning: profile x replica-budget sweep");
    println!("(GPT-OSS-120B, ep=8, b=768/rank, skewed decode)\n");
    println!(
        "{:<14} {:>7} {:>14} {:>8} {:>12} {:>10}",
        "profile", "budget", "step latency", "IR", "exposed_us", "xfer_1e/us"
    );
    for profile in [
        HardwareProfile::hopper_141(),
        HardwareProfile::hopper_lowbw(),
        HardwareProfile::compute_heavy(),
    ] {
        for budget in [0usize, 1, 3] {
            let mut cfg = Config::default();
            cfg.model.n_layers = 6;
            // flat single-node fabric via the fabric-era constructor
            cfg.cluster = Cluster::flat(8, profile.clone());
            let mut pc = ProbeConfig::default();
            pc.max_redundant = budget;
            let mut bal = Probe::new(&cfg, pc, 7);
            let mut sim = ClusterSim::new(cfg.model.clone(), cfg.cluster.clone());
            let mut rm = RoutingModel::calibrated(6, 128, 4, 4, 13);
            let mut lats = Vec::new();
            let mut irs = Vec::new();
            let mut exposed = 0.0;
            for step in 0..20 {
                let routing = rm.route_step(&vec![0u16; cfg.global_batch()]);
                let ds = decide_step(&mut bal, step, &routing);
                let out = sim.run_step(&routing, &ds);
                lats.push(out.latency);
                irs.push(out.mean_ir());
                exposed += out
                    .timelines
                    .iter()
                    .map(|t| t.exposed_overhead)
                    .sum::<f64>();
                rm.step_drift();
            }
            println!(
                "{:<14} {:>7} {:>11.2}ms {:>8.2} {:>12.1} {:>10.1}",
                profile.name,
                budget,
                mean(&lats) * 1e3,
                mean(&irs),
                exposed * 1e6,
                transfer_time(1, &cfg.model, &profile) * 1e6,
            );
        }
    }
    println!("\nreading: low-bandwidth fabrics pay more per replica (bigger");
    println!("transfer vs window) — the planner's dual budget caps replication");
    println!("exactly where the paper's hardware-aware constraint binds.");
}
