//! Multi-replica, load-aware serving: shard one skewed request stream
//! across N simulator-backed engine replicas and compare dispatch
//! policies — the data-parallel axis (HarMoEny / ExpertFlow style) on
//! top of PROBE's per-instance expert balancing.
//!
//! Run: `cargo run --release --example fleet_serving`

use probe::experiments::fleet::{run_cell, FleetParams, FleetWorkload};
use probe::server::dispatch::DispatchKind;
use probe::workload::Dataset;

fn main() {
    let mut p = FleetParams::default();
    p.requests_per_replica = 32;
    let workloads = [
        FleetWorkload {
            dataset: Dataset::Repeat,
            shift_to: None,
        },
        FleetWorkload {
            dataset: Dataset::Code,
            shift_to: Some(Dataset::Chinese),
        },
    ];
    println!("PROBE fleet serving: 4 sim-backed replicas, skewed traffic\n");
    println!(
        "{:<16} {:<16} {:>10} {:>10} {:>10} {:>8}",
        "dataset", "policy", "agg tok/s", "ttft p50", "ttft p99", "IR"
    );
    for w in &workloads {
        let mut base = 0.0;
        for policy in DispatchKind::ALL {
            let report = run_cell(&p, w, 4, policy);
            let ttft = report.merged_metrics().ttft_summary();
            let thr = report.aggregate_throughput();
            if policy == DispatchKind::RoundRobin {
                base = thr;
            }
            println!(
                "{:<16} {:<16} {:>10.0} {:>8.1}ms {:>8.1}ms {:>8.2}{}",
                w.label(),
                policy.name(),
                thr,
                ttft.p50 * 1e3,
                ttft.p99 * 1e3,
                report.mean_ir(),
                if policy != DispatchKind::RoundRobin && base > 0.0 {
                    format!("  ({:+.1}% vs rr)", (thr / base - 1.0) * 100.0)
                } else {
                    String::new()
                }
            );
        }
    }
    println!("\nreading: shortest-queue balances the lognormal work spread the");
    println!("round-robin baseline ignores; bounded-load domain affinity keeps");
    println!("semantic locality per replica while spilling under single-domain");
    println!("floods. Each replica is the SAME generic serving engine the PJRT");
    println!("path uses (engine::ServingEngine<SimExecutor>).");
}
