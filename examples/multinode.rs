//! Multi-node fabric sweep: 32 ranks across 4 nodes, NVSwitch inside a
//! node and RDMA rails between nodes, sweeping the inter-node bandwidth
//! ratio and comparing topology-aware vs topology-blind PROBE planning.
//!
//! Run: `cargo run --release --example multinode`

use probe::experiments::fabric::run_probe_on_fabric;

fn main() {
    println!("PROBE on a 32-rank / 4-node fabric (GPT-OSS-120B decode)");
    println!("NVSwitch 450 GB/s per port; rails = 2 per node\n");
    println!(
        "{:<12} {:<8} {:>14} {:>12} {:>12}",
        "inter/intra", "planner", "step latency", "exposed_us", "tok/s"
    );
    let steps = 12;
    let batch = 512;
    for ratio in [0.25, 0.125, 0.0625] {
        for aware in [true, false] {
            let (lat, exposed, tput) =
                run_probe_on_fabric(32, 4, ratio, 2, aware, steps, batch, 77);
            println!(
                "1/{:<10} {:<8} {:>11.2}ms {:>12.1} {:>12.0}",
                (1.0 / ratio).round() as usize,
                if aware { "aware" } else { "blind" },
                lat * 1e3,
                exposed * 1e6,
                tput
            );
        }
    }
    println!("\nreading: as rails shrink below ~1/8 of NVSwitch, blind");
    println!("planning keeps fetching replicas across nodes and exposes the");
    println!("transfer; topology-aware planning sources replicas inside the");
    println!("node and budgets the rails, keeping the prefetch hidden.");
}
