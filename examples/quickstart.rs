//! Quickstart: simulate PROBE vs the SGLang static-EP baseline on one
//! skewed decode workload and print the headline metrics.
//!
//! Run: `cargo run --release --example quickstart`

use probe::balancers::decide_step;
use probe::config::{BalancerKind, Config};
use probe::experiments::make_balancer;
use probe::routing::RoutingModel;
use probe::simulator::ClusterSim;
use probe::topology::{Cluster, HardwareProfile};
use probe::util::stats::mean;

fn main() {
    // Paper testbed: GPT-OSS-120B on 8x Hopper-141, b=768 tokens/rank,
    // built through the fabric API (flat = one NVSwitch node; see
    // examples/multinode.rs for multi-node fabrics).
    let mut cfg = Config::default();
    cfg.cluster = Cluster::flat(8, HardwareProfile::hopper_141());
    cfg.model.n_layers = 6; // representative layers (DESIGN.md)
    cfg.batch_per_rank = 768;

    let mut results = Vec::new();
    for kind in [BalancerKind::StaticEp, BalancerKind::Eplb, BalancerKind::Probe] {
        let mut sim = ClusterSim::new(cfg.model.clone(), cfg.cluster.clone());
        let mut bal = make_balancer(kind, &cfg, 42);
        // single-domain traffic = the paper's semantic-burst regime
        let mut rm = RoutingModel::calibrated(6, 128, 4, 4, 42);
        let mut lat = Vec::new();
        let mut irs = Vec::new();
        for step in 0..30 {
            let routing = rm.route_step(&vec![0u16; cfg.global_batch()]);
            let ds = decide_step(bal.as_mut(), step, &routing);
            let out = sim.run_step(&routing, &ds);
            lat.push(out.latency);
            irs.push(out.mean_ir());
            rm.step_drift();
        }
        results.push((kind.name(), mean(&lat), mean(&irs)));
    }

    println!("GPT-OSS-120B, ep=8, b=768/rank, skewed single-domain decode\n");
    println!("{:<10} {:>16} {:>10} {:>10}", "system", "step latency", "IR", "speedup");
    let base = results[0].1;
    for (name, lat, ir) in &results {
        println!(
            "{:<10} {:>13.2}ms {:>10.2} {:>9.2}x",
            name,
            lat * 1e3,
            ir,
            base / lat
        );
    }
    println!("\nPROBE hides predict/plan/prefetch on the aux track; see");
    println!("`cargo bench` for the full figure reproductions.");
}
