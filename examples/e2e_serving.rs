//! END-TO-END driver (mandated): load the REAL small MoE model compiled
//! by `make artifacts` (JAX+Pallas -> HLO text -> PJRT CPU) and serve
//! batched requests through the threaded server, reporting
//! latency/throughput, live IR, and predictor fidelity measured on real
//! router traces. Proves all three layers compose:
//!   L1 Pallas grouped-GEMM kernel -> L2 JAX transformer -> L3 rust
//!   coordinator (continuous batching + PROBE metrics stack).
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use probe::coordinator::real::RealCoordinator;
use probe::runtime::Engine;
use probe::server::{spawn, ServeRequest};
use probe::util::Rng;

fn main() {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== PROBE end-to-end serving (real model via PJRT) ==");

    // The engine is built inside the leader thread (PJRT is not Send).
    let dir2 = dir.clone();
    let handle = spawn(
        move || {
            let engine = Engine::load(&dir2)?;
            println!(
                "loaded model: {} weight tensors, {} layers, {} experts (top-{}), vocab {}",
                engine.n_params(),
                engine.cfg().n_layers,
                engine.cfg().n_experts,
                engine.cfg().top_k,
                engine.cfg().vocab
            );
            Ok(RealCoordinator::new(engine, 8, 0))
        },
        /*max_steps=*/ 4000,
    );

    // Submit a mixed-domain batch of requests (the paper's diverse
    // concurrent traffic), including the high-skew "repeat" domain.
    let n_requests = 24;
    let mut rng = Rng::new(11);
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        handle.submit(ServeRequest {
            id: i,
            domain: (i % 4) as u16,
            prompt_len: 8 + rng.next_usize(24),
            max_new_tokens: 16 + rng.next_usize(32),
            arrival: 0.0,
        });
    }

    let mut done = 0;
    while done < n_requests {
        match handle.recv() {
            Ok(resp) => {
                done += 1;
                println!(
                    "  request {:>2} done: {} tokens, TTFT {:>7.1}ms, TPOT {:>6.2}ms",
                    resp.id,
                    resp.tokens_out,
                    resp.ttft * 1e3,
                    resp.tpot.unwrap_or(0.0) * 1e3
                );
            }
            Err(_) => break,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = handle.shutdown();
    println!("\n== results ==");
    println!(
        "completed {}/{} requests in {:.2}s wall ({} decode steps)",
        stats.completed, n_requests, wall, stats.steps
    );
    println!(
        "decode throughput {:.1} tok/s | TTFT p50 {:.1}ms | TPOT p50 {:.2}ms",
        stats.throughput,
        stats.ttft_p50 * 1e3,
        stats.tpot_p50 * 1e3
    );
    println!(
        "mean IR of the REAL router at virtual ep=8: {:.2} (paper Fig.2 regime)",
        stats.mean_ir
    );
    assert!(stats.completed == n_requests as usize, "not all requests finished");
    assert!(stats.throughput > 0.0);
    println!("\nE2E OK: Pallas kernel -> JAX HLO -> PJRT -> rust serving loop");
}
